//! Bounded FIFO channel with backpressure + instrumentation — the
//! `hls::stream<T>` analogue.
//!
//! Semantics match the hardware stream: fixed capacity chosen at
//! construction, writers block when full (backpressure), readers block
//! when empty, and the channel records high-water occupancy and stall
//! counts so [`super::depth`] can size depths the way the paper's
//! C/RTL cosimulation does.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::telemetry::{Gauge, MetricsRegistry};

/// Error returned by `recv` when the channel is closed and drained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

/// Error returned by [`Fifo::try_send`]; carries the rejected value
/// back so the caller can shed it with a typed response (admission
/// control) or re-route it.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// The FIFO is at capacity. Not counted as a `write_stall`: the
    /// caller chose not to wait, so no writer was ever stalled.
    Full(T),
    /// The FIFO is closed.
    Closed(T),
}

/// Instrumentation counters for one FIFO.
#[derive(Debug, Default)]
pub struct FifoStats {
    /// Total elements pushed.
    pub pushes: AtomicU64,
    /// Total elements popped.
    pub pops: AtomicU64,
    /// Times a writer found the FIFO full and had to wait.
    pub write_stalls: AtomicU64,
    /// Times a reader found the FIFO empty and had to wait.
    pub read_stalls: AtomicU64,
    /// Maximum occupancy ever observed (high-water mark).
    pub high_water: AtomicU64,
}

impl FifoStats {
    pub fn snapshot(&self) -> FifoStatsSnapshot {
        FifoStatsSnapshot {
            pushes: self.pushes.load(Ordering::Relaxed),
            pops: self.pops.load(Ordering::Relaxed),
            write_stalls: self.write_stalls.load(Ordering::Relaxed),
            read_stalls: self.read_stalls.load(Ordering::Relaxed),
            high_water: self.high_water.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of [`FifoStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FifoStatsSnapshot {
    pub pushes: u64,
    pub pops: u64,
    pub write_stalls: u64,
    pub read_stalls: u64,
    pub high_water: u64,
}

impl FifoStatsSnapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("pushes", Json::from(self.pushes as f64)),
            ("pops", Json::from(self.pops as f64)),
            ("write_stalls", Json::from(self.write_stalls as f64)),
            ("read_stalls", Json::from(self.read_stalls as f64)),
            ("high_water", Json::from(self.high_water as f64)),
        ])
    }
}

/// Registry gauges mirrored on every push/pop once the FIFO is
/// [`instrument`](Fifo::instrument)ed: live occupancy and high-water.
struct FifoGauges {
    depth: Gauge,
    high_water: Gauge,
}

struct Inner<T> {
    q: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    stats: FifoStats,
    gauges: OnceLock<FifoGauges>,
}

impl<T> Inner<T> {
    fn mirror_depth(&self, occ: usize) {
        if let Some(g) = self.gauges.get() {
            g.depth.set(occ as i64);
            g.high_water.raise(occ as i64);
        }
    }
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer FIFO.
///
/// Clone to share; `close()` (or dropping all senders via explicit
/// close) wakes blocked readers, which then drain and get `RecvError`.
pub struct Fifo<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Fifo<T> {
    fn clone(&self) -> Self {
        Fifo { inner: self.inner.clone() }
    }
}

impl<T> Fifo<T> {
    /// Create with fixed capacity (>= 1, like an HLS stream depth).
    pub fn with_capacity(capacity: usize) -> Fifo<T> {
        assert!(capacity >= 1, "FIFO depth must be >= 1");
        Fifo {
            inner: Arc::new(Inner {
                q: Mutex::new(State { buf: VecDeque::with_capacity(capacity), closed: false }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                capacity,
                stats: FifoStats::default(),
                gauges: OnceLock::new(),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Attach live occupancy gauges under `prefix` in `reg`:
    /// `{prefix}.depth` (current occupancy), `{prefix}.high_water`
    /// (max occupancy seen since instrumentation) and
    /// `{prefix}.capacity` (static). Idempotent; the first caller
    /// wins. Uninstrumented FIFOs pay one relaxed atomic load per op.
    pub fn instrument(&self, reg: &MetricsRegistry, prefix: &str) {
        let depth = reg.gauge(&format!("{prefix}.depth"));
        let high_water = reg.gauge(&format!("{prefix}.high_water"));
        reg.gauge(&format!("{prefix}.capacity")).set(self.inner.capacity as i64);
        let occ = self.len();
        let _ = self.inner.gauges.set(FifoGauges { depth, high_water });
        self.inner.mirror_depth(occ);
    }

    /// Blocking push (backpressure). Returns Err(v) if the FIFO closed.
    pub fn send(&self, v: T) -> Result<(), T> {
        let inner = &*self.inner;
        let mut st = inner.q.lock().unwrap();
        if st.buf.len() >= inner.capacity && !st.closed {
            inner.stats.write_stalls.fetch_add(1, Ordering::Relaxed);
            while st.buf.len() >= inner.capacity && !st.closed {
                st = inner.not_full.wait(st).unwrap();
            }
        }
        if st.closed {
            return Err(v);
        }
        st.buf.push_back(v);
        let occ = st.buf.len() as u64;
        inner.stats.pushes.fetch_add(1, Ordering::Relaxed);
        inner.stats.high_water.fetch_max(occ, Ordering::Relaxed);
        inner.mirror_depth(occ as usize);
        drop(st);
        inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push (admission control). `Full`/`Closed` hand the
    /// value back untouched; a rejected send is never counted as a
    /// push or a write stall — the stats see only traffic that
    /// actually entered the stream.
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        let inner = &*self.inner;
        let mut st = inner.q.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(v));
        }
        if st.buf.len() >= inner.capacity {
            return Err(TrySendError::Full(v));
        }
        st.buf.push_back(v);
        let occ = st.buf.len() as u64;
        inner.stats.pushes.fetch_add(1, Ordering::Relaxed);
        inner.stats.high_water.fetch_max(occ, Ordering::Relaxed);
        inner.mirror_depth(occ as usize);
        drop(st);
        inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. `Err(RecvError)` only after close + drain.
    pub fn recv(&self) -> Result<T, RecvError> {
        let inner = &*self.inner;
        let mut st = inner.q.lock().unwrap();
        if st.buf.is_empty() && !st.closed {
            inner.stats.read_stalls.fetch_add(1, Ordering::Relaxed);
            while st.buf.is_empty() && !st.closed {
                st = inner.not_empty.wait(st).unwrap();
            }
        }
        match st.buf.pop_front() {
            Some(v) => {
                inner.stats.pops.fetch_add(1, Ordering::Relaxed);
                inner.mirror_depth(st.buf.len());
                drop(st);
                inner.not_full.notify_one();
                Ok(v)
            }
            None => Err(RecvError), // closed and drained
        }
    }

    /// Non-blocking pop.
    pub fn try_recv(&self) -> Option<T> {
        let inner = &*self.inner;
        let mut st = inner.q.lock().unwrap();
        let v = st.buf.pop_front();
        if v.is_some() {
            inner.stats.pops.fetch_add(1, Ordering::Relaxed);
            inner.mirror_depth(st.buf.len());
            inner.not_full.notify_one();
        }
        v
    }

    /// Close the channel: senders fail, readers drain then stop.
    pub fn close(&self) {
        let inner = &*self.inner;
        let mut st = inner.q.lock().unwrap();
        st.closed = true;
        drop(st);
        inner.not_empty.notify_all();
        inner.not_full.notify_all();
    }

    /// Reverse a `close()`: new sends are accepted again. The channel
    /// object (and every clone held by peers) keeps working — this is
    /// what lets a resurrected replica reuse its queue without
    /// re-plumbing the scheduler. Stats and instrumentation carry
    /// over; anything left in the buffer stays there.
    pub fn reopen(&self) {
        let inner = &*self.inner;
        let mut st = inner.q.lock().unwrap();
        st.closed = false;
        drop(st);
        // Readers blocked in `recv` were already woken by `close()`;
        // nobody waits on a closed channel, so no notify is needed.
    }

    pub fn is_closed(&self) -> bool {
        self.inner.q.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> FifoStatsSnapshot {
        self.inner.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order_preserved() {
        let f = Fifo::with_capacity(4);
        for i in 0..4 {
            f.send(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(f.recv().unwrap(), i);
        }
    }

    #[test]
    fn backpressure_blocks_writer_until_reader_drains() {
        let f = Fifo::with_capacity(2);
        f.send(1).unwrap();
        f.send(2).unwrap();
        let f2 = f.clone();
        let h = thread::spawn(move || {
            f2.send(3).unwrap(); // must block until a pop
            f2.stats().write_stalls
        });
        thread::sleep(Duration::from_millis(30));
        assert_eq!(f.len(), 2, "writer should be blocked");
        assert_eq!(f.recv().unwrap(), 1);
        let stalls = h.join().unwrap();
        assert!(stalls >= 1);
        assert_eq!(f.recv().unwrap(), 2);
        assert_eq!(f.recv().unwrap(), 3);
    }

    #[test]
    fn reader_blocks_until_data() {
        let f: Fifo<u32> = Fifo::with_capacity(1);
        let f2 = f.clone();
        let h = thread::spawn(move || f2.recv().unwrap());
        thread::sleep(Duration::from_millis(20));
        f.send(7).unwrap();
        assert_eq!(h.join().unwrap(), 7);
        assert!(f.stats().read_stalls >= 1);
    }

    #[test]
    fn close_drains_then_errors() {
        let f = Fifo::with_capacity(4);
        f.send(1).unwrap();
        f.send(2).unwrap();
        f.close();
        assert_eq!(f.recv(), Ok(1));
        assert_eq!(f.recv(), Ok(2));
        assert_eq!(f.recv(), Err(RecvError));
        assert_eq!(f.send(3), Err(3));
    }

    #[test]
    fn close_wakes_blocked_reader() {
        let f: Fifo<u32> = Fifo::with_capacity(1);
        let f2 = f.clone();
        let h = thread::spawn(move || f2.recv());
        thread::sleep(Duration::from_millis(20));
        f.close();
        assert_eq!(h.join().unwrap(), Err(RecvError));
    }

    #[test]
    fn close_wakes_blocked_writer() {
        let f = Fifo::with_capacity(1);
        f.send(1).unwrap();
        let f2 = f.clone();
        let h = thread::spawn(move || f2.send(2));
        thread::sleep(Duration::from_millis(20));
        f.close();
        assert_eq!(h.join().unwrap(), Err(2));
    }

    #[test]
    fn high_water_tracks_max_occupancy() {
        let f = Fifo::with_capacity(8);
        for i in 0..5 {
            f.send(i).unwrap();
        }
        f.recv().unwrap();
        f.send(9).unwrap();
        assert_eq!(f.stats().high_water, 5);
    }

    #[test]
    fn mpmc_sums_consistent() {
        let f = Fifo::with_capacity(16);
        let mut producers = vec![];
        for p in 0..4 {
            let f = f.clone();
            producers.push(thread::spawn(move || {
                for i in 0..1000u64 {
                    f.send(p * 1000 + i).unwrap();
                }
            }));
        }
        let mut consumers = vec![];
        for _ in 0..3 {
            let f = f.clone();
            consumers.push(thread::spawn(move || {
                let mut sum = 0u64;
                while let Ok(v) = f.recv() {
                    sum += v;
                }
                sum
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        f.close();
        let total: u64 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        let expect: u64 = (0..4u64).map(|p| (0..1000).map(|i| p * 1000 + i).sum::<u64>()).sum();
        assert_eq!(total, expect);
        let s = f.stats();
        assert_eq!(s.pushes, 4000);
        assert_eq!(s.pops, 4000);
    }

    #[test]
    fn try_send_full_returns_value_without_stall_or_push() {
        let f = Fifo::with_capacity(2);
        f.try_send(1).unwrap();
        f.try_send(2).unwrap();
        assert_eq!(f.try_send(3), Err(TrySendError::Full(3)));
        let s = f.stats();
        assert_eq!(s.pushes, 2, "rejected send must not count as a push");
        assert_eq!(s.write_stalls, 0, "try_send never stalls");
        assert_eq!(f.recv(), Ok(1));
        f.try_send(4).unwrap();
        assert_eq!(f.recv(), Ok(2));
        assert_eq!(f.recv(), Ok(4));
    }

    #[test]
    fn try_send_closed_returns_value() {
        let f = Fifo::with_capacity(2);
        f.close();
        assert_eq!(f.try_send(9), Err(TrySendError::Closed(9)));
        assert_eq!(f.stats().pushes, 0);
    }

    #[test]
    fn reopen_after_close_accepts_new_traffic_on_old_clones() {
        let f = Fifo::with_capacity(2);
        let peer = f.clone(); // a scheduler's long-lived handle
        f.send(1).unwrap();
        f.close();
        assert_eq!(peer.send(2), Err(2));
        assert_eq!(f.recv(), Ok(1));
        assert_eq!(f.recv(), Err(RecvError));
        f.reopen();
        assert!(!peer.is_closed());
        peer.send(3).unwrap(); // the old clone works again
        f.try_send(4).unwrap();
        assert_eq!(f.recv(), Ok(3));
        assert_eq!(f.recv(), Ok(4));
        // Stats accumulate across incarnations of the channel.
        assert_eq!(f.stats().pushes, 3);
    }

    #[test]
    #[should_panic(expected = "depth must be >= 1")]
    fn zero_capacity_rejected() {
        let _ = Fifo::<u8>::with_capacity(0);
    }

    #[test]
    fn instrumented_fifo_mirrors_depth_gauges() {
        let reg = MetricsRegistry::new();
        let f = Fifo::with_capacity(4);
        f.send(1).unwrap(); // pre-instrumentation occupancy picked up
        f.instrument(&reg, "stage0.shard0.input");
        assert_eq!(reg.gauge("stage0.shard0.input.depth").get(), 1);
        assert_eq!(reg.gauge("stage0.shard0.input.capacity").get(), 4);
        f.send(2).unwrap();
        f.send(3).unwrap();
        assert_eq!(reg.gauge("stage0.shard0.input.depth").get(), 3);
        assert_eq!(reg.gauge("stage0.shard0.input.high_water").get(), 3);
        f.recv().unwrap();
        assert_eq!(f.try_recv(), Some(2));
        assert_eq!(reg.gauge("stage0.shard0.input.depth").get(), 1);
        // High water is sticky.
        assert_eq!(reg.gauge("stage0.shard0.input.high_water").get(), 3);
        // Second instrumentation attempt is a no-op (first wins): ops
        // keep mirroring into the original gauges.
        f.instrument(&reg, "other");
        f.recv().unwrap();
        assert_eq!(reg.gauge("stage0.shard0.input.depth").get(), 0);
        assert_eq!(reg.gauge("other.depth").get(), 0, "losing prefix never receives updates");
    }
}
