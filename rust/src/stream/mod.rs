//! Stream-based dataflow runtime — the software realization of the
//! paper's accelerator architecture (Figs. 2-3).
//!
//! Vitis HLS compiles `#pragma HLS DATAFLOW` + `hls::stream` into
//! concurrently running stages connected by fixed-depth FIFOs with
//! backpressure. This module is that execution model in rust:
//!
//! - [`fifo`] — bounded FIFO channels with occupancy/stall
//!   instrumentation (the `hls::stream` analogue);
//! - [`pipeline`] — task-level pipeline builder: one thread per stage,
//!   stages decoupled by FIFOs (the `DATAFLOW` analogue), plus a
//!   sequential executor over the *same* stage functions (Fig. 3 left:
//!   the unoptimized baseline for the ablation bench);
//! - [`depth`] — discrete-event FIFO depth analysis: the software
//!   mirror of the paper's C/RTL cosimulation step that "finalizes FIFO
//!   depths and confirms that no deadlocks can occur".

pub mod depth;
pub mod fifo;
pub mod pipeline;

pub use fifo::{Fifo, FifoStats, RecvError, TrySendError};
pub use pipeline::{Pipeline, PipelineReport, StageReport};
