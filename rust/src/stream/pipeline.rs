//! Task-level pipeline — the `#pragma HLS DATAFLOW` analogue.
//!
//! A pipeline is a chain of stages, each running on its own thread,
//! decoupled by bounded [`Fifo`]s: a stage starts processing as soon as
//! partial data is available and stalls only on FIFO backpressure,
//! exactly like the paper's Fig. 3 (right). The same stage closures can
//! also be run by [`Pipeline::run_sequential`], which models Fig. 3
//! (left): each item traverses all stages before the next enters — the
//! ablation baseline for the paper's "~70% improvement" claim
//! (`benches/ablation_dataflow.rs`).

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::bcpnn::sparse::TILE;
use crate::bcpnn::{BufPool, LayerGraph, Network};
use crate::data::encode::{encode_image_in_place, encode_tile_in_place, pack_tile, unpack_lane};

use super::fifo::{Fifo, FifoStatsSnapshot};

/// Per-stage execution report.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub items: u64,
    /// Time spent inside the stage function (service time).
    pub busy: Duration,
    /// Wall time of the stage thread from first to last item.
    pub wall: Duration,
    /// Stats of the stage's *output* FIFO (None for the sink).
    pub output_fifo: Option<FifoStatsSnapshot>,
}

impl StageReport {
    /// Fraction of wall time the stage was doing useful work.
    pub fn utilization(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.busy.as_secs_f64() / self.wall.as_secs_f64()
    }
}

/// Whole-pipeline report.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub stages: Vec<StageReport>,
    pub items: u64,
    pub wall: Duration,
}

impl PipelineReport {
    pub fn throughput_items_per_sec(&self) -> f64 {
        self.items as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// The stage limiting throughput (highest busy time).
    pub fn bottleneck(&self) -> Option<&StageReport> {
        self.stages.iter().max_by(|a, b| a.busy.cmp(&b.busy))
    }
}

/// Builder for a dataflow pipeline. `T` is the element type currently
/// flowing out of the last registered stage.
pub struct Pipeline<T: Send + 'static> {
    rx: Fifo<T>,
    handles: Vec<thread::JoinHandle<StageReport>>,
}

impl<T: Send + 'static> Pipeline<T> {
    /// Start a pipeline from an iterator source. `depth` is the source
    /// FIFO depth (the "input stream" of the accelerator).
    pub fn source<I>(name: &str, depth: usize, items: I) -> Pipeline<T>
    where
        I: IntoIterator<Item = T> + Send + 'static,
    {
        let fifo = Fifo::with_capacity(depth);
        let out = fifo.clone();
        let name = name.to_string();
        let h = thread::spawn(move || {
            let start = Instant::now();
            let mut n = 0u64;
            let mut busy = Duration::ZERO;
            for v in items {
                let t0 = Instant::now();
                n += 1;
                busy += t0.elapsed();
                if out.send(v).is_err() {
                    break;
                }
            }
            out.close();
            StageReport {
                name,
                items: n,
                busy,
                wall: start.elapsed(),
                output_fifo: Some(out.stats()),
            }
        });
        Pipeline { rx: fifo, handles: vec![h] }
    }

    /// Add a map stage on its own thread, connected by a FIFO of the
    /// given depth.
    pub fn stage<U, F>(self, name: &str, depth: usize, mut f: F) -> Pipeline<U>
    where
        U: Send + 'static,
        F: FnMut(T) -> U + Send + 'static,
    {
        let out = Fifo::with_capacity(depth);
        let out_w = out.clone();
        let rx = self.rx;
        let name = name.to_string();
        let mut handles = self.handles;
        handles.push(thread::spawn(move || {
            let start = Instant::now();
            let mut n = 0u64;
            let mut busy = Duration::ZERO;
            while let Ok(v) = rx.recv() {
                let t0 = Instant::now();
                let u = f(v);
                busy += t0.elapsed();
                n += 1;
                if out_w.send(u).is_err() {
                    break;
                }
            }
            out_w.close();
            StageReport {
                name,
                items: n,
                busy,
                wall: start.elapsed(),
                output_fifo: Some(out_w.stats()),
            }
        }));
        Pipeline { rx: out, handles }
    }

    /// Terminate with a sink stage on the calling thread; joins all
    /// stage threads and returns the report.
    pub fn sink<F>(self, name: &str, mut f: F) -> PipelineReport
    where
        F: FnMut(T),
    {
        let start = Instant::now();
        let mut n = 0u64;
        let mut busy = Duration::ZERO;
        while let Ok(v) = self.rx.recv() {
            let t0 = Instant::now();
            f(v);
            busy += t0.elapsed();
            n += 1;
        }
        let sink_report = StageReport {
            name: name.to_string(),
            items: n,
            busy,
            wall: start.elapsed(),
            output_fifo: None,
        };
        let mut stages: Vec<StageReport> =
            self.handles.into_iter().map(|h| h.join().expect("stage panicked")).collect();
        stages.push(sink_report);
        PipelineReport { stages, items: n, wall: start.elapsed() }
    }

    /// Collect all outputs into a Vec (convenience sink).
    pub fn collect(self) -> (Vec<T>, PipelineReport) {
        let mut out = Vec::new();
        // Drain on this thread; cannot use `sink` directly because the
        // closure borrows `out`.
        let start = Instant::now();
        let mut n = 0u64;
        while let Ok(v) = self.rx.recv() {
            out.push(v);
            n += 1;
        }
        let mut stages: Vec<StageReport> =
            self.handles.into_iter().map(|h| h.join().expect("stage panicked")).collect();
        stages.push(StageReport {
            name: "collect".into(),
            items: n,
            busy: Duration::ZERO,
            wall: start.elapsed(),
            output_fifo: None,
        });
        (out, PipelineReport { stages, items: n, wall: start.elapsed() })
    }
}

/// Build and run the layer-graph inference dataflow: `encode`, then
/// one `support -> softmax` stage pair per hidden layer, then the
/// classifier head — every stage on its own thread, chained by FIFOs
/// of `depth`, exactly how the FPGA would chain one kernel per layer.
/// Output order matches the input and each probability vector is
/// bitwise identical to [`LayerGraph::infer`].
///
/// Allocation: the encode stage expands each image *in place* (one
/// buffer per item end to end — the n -> 2n growth still reallocates
/// for capacity-exact inputs), the softmax stages run in place, and
/// the support stages write into buffers recycled from their consumed
/// inputs via a per-stage [`BufPool`] — a stage allocates only when
/// its output is wider than every buffer it has pooled (a fresh
/// transport buffer can't flow back upstream in a pure dataflow
/// chain). The head allocates its outputs exact-sized (they are
/// retained by the caller). The seed path's per-image `bj` clone and
/// dense mask walk are gone everywhere.
pub fn layer_graph_pipeline(
    graph: &Arc<LayerGraph>,
    images: Vec<Vec<f32>>,
    depth: usize,
) -> (Vec<Vec<f32>>, PipelineReport) {
    let mut p: Pipeline<Vec<f32>> = Pipeline::source("images", depth, images)
        .stage("encode", depth, move |mut img: Vec<f32>| {
            encode_image_in_place(&mut img);
            img
        });
    for l in 0..graph.layers.len() {
        let gs = graph.clone();
        let mut pool = BufPool::new();
        p = p.stage(&format!("support{l}"), depth, move |x: Vec<f32>| {
            let mut s = pool.get();
            gs.layers[l].support_masked_into(&x, &mut s);
            pool.put(x);
            s
        });
        let ga = graph.clone();
        p = p.stage(&format!("softmax{l}"), depth, move |mut s: Vec<f32>| {
            let d = ga.layers[l].dims;
            Network::hc_softmax(&mut s, d.hc_out, d.mc_out, ga.cfg.gain);
            s
        });
    }
    let gh = graph.clone();
    // The head's outputs are retained by `collect` — allocate them
    // exact-sized (n_classes) instead of recycling wide activity
    // buffers into them; the spent activity vec ends its transport
    // loop here.
    p.stage("head", depth, move |y: Vec<f32>| gh.head.activate_dense(&y))
        .collect()
}

/// The batched twin of [`layer_graph_pipeline`]: the same stage chain,
/// but every FIFO item is an AoSoA tile of up to
/// [`TILE`](crate::bcpnn::sparse::TILE) lane-interleaved images — each
/// stage walks its weight spans once per tile instead of once per
/// image, so the stream's weight-bandwidth cost drops by the lane
/// count while stage overlap stays. Items are `(lanes, tile)` pairs:
/// the image tiles are packed up front, the encode stage expands the
/// pixel tile in place, and the tail unpacks per-image results in
/// order. Output per image is bitwise identical to
/// [`LayerGraph::infer`] (lane-private kernels; ragged tail tiles pad
/// with zero lanes).
pub fn layer_graph_tile_pipeline(
    graph: &Arc<LayerGraph>,
    images: Vec<Vec<f32>>,
    depth: usize,
) -> (Vec<Vec<f32>>, PipelineReport) {
    let n = images.len();
    // Pack lazily inside the source: tiles materialize one at a time
    // as the pipeline pulls, so peak memory is the input batch plus
    // the (depth-bounded) in-flight tiles — never a full second copy.
    let mut pending = images.into_iter();
    let tiles = std::iter::from_fn(move || {
        let lanes: Vec<Vec<f32>> = pending.by_ref().take(TILE).collect();
        if lanes.is_empty() {
            return None;
        }
        let mut buf = Vec::new();
        pack_tile(&lanes, &mut buf);
        Some((lanes.len(), buf))
    });
    let mut p: Pipeline<(usize, Vec<f32>)> = Pipeline::source("tiles", depth, tiles)
        .stage("encode", depth, move |(lanes, mut buf): (usize, Vec<f32>)| {
            encode_tile_in_place(&mut buf);
            (lanes, buf)
        });
    for l in 0..graph.layers.len() {
        let gs = graph.clone();
        let mut pool = BufPool::new();
        p = p.stage(&format!("support{l}"), depth, move |(lanes, x): (usize, Vec<f32>)| {
            let mut s = pool.get();
            gs.layers[l].support_masked_tile_into(&x, &mut s);
            pool.put(x);
            (lanes, s)
        });
        let ga = graph.clone();
        p = p.stage(&format!("softmax{l}"), depth, move |(lanes, mut s): (usize, Vec<f32>)| {
            let d = ga.layers[l].dims;
            Network::hc_softmax_tile(&mut s, d.hc_out, d.mc_out, ga.cfg.gain);
            (lanes, s)
        });
    }
    let gh = graph.clone();
    let (tile_out, rep) = p
        .stage("head", depth, move |(lanes, y): (usize, Vec<f32>)| {
            let mut out = Vec::new();
            gh.head.activate_dense_tile_into(&y, &mut out);
            (lanes, out)
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    for (lanes, t) in tile_out {
        for lane in 0..lanes {
            out.push(unpack_lane(&t, lane));
        }
    }
    (out, rep)
}

/// Run the same logical stages strictly sequentially (Fig. 3 left):
/// each item passes through every function before the next item starts.
/// This is the paper's "initial unoptimized sequential implementation".
pub fn run_sequential<T, F>(items: Vec<T>, mut stages: Vec<(&str, F)>) -> PipelineReport
where
    F: FnMut(T) -> T,
{
    let start = Instant::now();
    let mut busies = vec![Duration::ZERO; stages.len()];
    let mut n = 0u64;
    for item in items {
        let mut v = item;
        for (i, (_, f)) in stages.iter_mut().enumerate() {
            let t0 = Instant::now();
            v = f(v);
            busies[i] += t0.elapsed();
        }
        n += 1;
    }
    let wall = start.elapsed();
    let reports = stages
        .iter()
        .zip(busies)
        .map(|((name, _), busy)| StageReport {
            name: name.to_string(),
            items: n,
            busy,
            wall,
            output_fifo: None,
        })
        .collect();
    PipelineReport { stages: reports, items: n, wall }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_maps_in_order() {
        let (out, rep) = Pipeline::source("src", 4, 0..100)
            .stage("double", 4, |x: i32| x * 2)
            .stage("inc", 4, |x: i32| x + 1)
            .collect();
        assert_eq!(out, (0..100).map(|x| x * 2 + 1).collect::<Vec<_>>());
        assert_eq!(rep.items, 100);
        assert_eq!(rep.stages.len(), 4); // src, double, inc, collect
    }

    #[test]
    fn sink_report_counts() {
        let mut sum = 0i64;
        let rep = Pipeline::source("src", 2, 1..=10i64)
            .stage("sq", 2, |x| x * x)
            .sink("sum", |x| sum += x);
        assert_eq!(rep.items, 10);
        assert_eq!(sum, (1..=10i64).map(|x| x * x).sum::<i64>());
    }

    #[test]
    fn dataflow_overlaps_stages() {
        // Two stages each sleeping 1ms/item: sequential = ~2ms/item,
        // dataflow = ~1ms/item. Check for a robust >1.3x speedup.
        let n = 40;
        let work = |x: u64| {
            std::thread::sleep(Duration::from_millis(1));
            x
        };
        let seq = run_sequential(
            (0..n).collect(),
            vec![("a", Box::new(work) as Box<dyn FnMut(u64) -> u64>),
                 ("b", Box::new(work) as Box<dyn FnMut(u64) -> u64>)],
        );
        let (_, par) = Pipeline::source("src", 8, 0..n)
            .stage("a", 8, work)
            .stage("b", 8, work)
            .collect();
        let speedup = seq.wall.as_secs_f64() / par.wall.as_secs_f64();
        assert!(speedup > 1.3, "dataflow speedup only {speedup:.2}x");
    }

    #[test]
    fn bottleneck_identified() {
        let (_, rep) = Pipeline::source("src", 4, 0..20u64)
            .stage("fast", 4, |x| x + 1)
            .stage("slow", 4, |x| {
                std::thread::sleep(Duration::from_millis(2));
                x
            })
            .collect();
        assert_eq!(rep.bottleneck().unwrap().name, "slow");
    }

    #[test]
    fn utilization_bounded() {
        let (_, rep) = Pipeline::source("src", 4, 0..50u64)
            .stage("s", 4, |x| x)
            .collect();
        for s in &rep.stages {
            let u = s.utilization();
            assert!((0.0..=1.0 + 1e-9).contains(&u), "{}: {u}", s.name);
        }
    }

    #[test]
    fn sequential_report_shapes() {
        let rep = run_sequential(
            vec![1, 2, 3],
            vec![("x", Box::new(|v: i32| v) as Box<dyn FnMut(i32) -> i32>)],
        );
        assert_eq!(rep.items, 3);
        assert_eq!(rep.stages.len(), 1);
    }

    #[test]
    fn empty_source_flows_through() {
        let (out, rep) = Pipeline::source("src", 1, Vec::<u8>::new())
            .stage("s", 1, |x| x)
            .collect();
        assert!(out.is_empty());
        assert_eq!(rep.items, 0);
    }

    #[test]
    fn tile_pipeline_bitwise_matches_infer_with_ragged_tail() {
        use crate::config::by_name;

        let cfg = by_name("toy-deep").unwrap();
        let graph = Arc::new(LayerGraph::new(cfg.clone(), 13));
        // TILE + 3 images: one full tile + a ragged 3-lane tail.
        let images: Vec<Vec<f32>> = (0..TILE + 3)
            .map(|i| vec![0.07 * i as f32; cfg.hc_in()])
            .collect();
        let (out, rep) = layer_graph_tile_pipeline(&graph, images.clone(), 4);
        assert_eq!(rep.stages.len(), 3 + 2 * cfg.n_layers() + 1);
        assert_eq!(rep.items as usize, 2); // two tiles streamed
        assert_eq!(out.len(), images.len());
        for (k, (img, probs)) in images.iter().zip(&out).enumerate() {
            let want = graph.infer(img);
            let gb: Vec<u32> = probs.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "image {k}");
        }
    }

    #[test]
    fn layer_graph_pipeline_has_one_stage_pair_per_layer() {
        use crate::config::by_name;

        let cfg = by_name("toy-deep").unwrap();
        let graph = Arc::new(LayerGraph::new(cfg.clone(), 5));
        let images: Vec<Vec<f32>> =
            (0..6).map(|i| vec![0.1 * i as f32; cfg.hc_in()]).collect();
        let (out, rep) = layer_graph_pipeline(&graph, images.clone(), 4);
        // source + encode + 2*(support, softmax) + head + collect.
        assert_eq!(rep.stages.len(), 3 + 2 * cfg.n_layers() + 1);
        assert_eq!(out.len(), images.len());
        for (img, probs) in images.iter().zip(&out) {
            assert_eq!(probs, &graph.infer(img), "pipeline diverges");
        }
    }
}
