//! Autotuner system tests (DESIGN.md §9):
//!
//! - the monotone structure the pruning relies on: `plan_hybrid`'s
//!   best bottleneck never gets worse when a homogeneous fleet grows
//!   by one device;
//! - determinism: two identical `tune` runs emit byte-identical
//!   outcome JSON (and byte-identical saved specs);
//! - the CI-gated "never worse" invariant: for every registry config
//!   the winner's modeled throughput is >= every pure strategy the
//!   search subsumes (pure pipeline, pure shard, default hybrid);
//! - infeasible workloads fail with the binding constraint named;
//! - spec round-trips: tune -> save -> load -> identical spec, and
//!   spec -> serve -> report with the spec's threads / precision /
//!   replica topology actually in effect.

use std::time::Duration;

use bcpnn_accel::bcpnn::{LayerGraph, QuantFormat};
use bcpnn_accel::cluster::{plan_hybrid, ClusterConfig, ClusterServer, Fleet};
use bcpnn_accel::config::{by_name, registry, BackendKind, DeploymentSpec, FleetSpec};
use bcpnn_accel::coordinator::{GraphBackend, InferenceServer, ServerConfig};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::tune::{plans_for_spec, tune, TuneOptions, Workload};

#[test]
fn hybrid_bottleneck_monotone_in_fleet_size() {
    // The tuner's dominance prune assumes: on a homogeneous fleet,
    // adding a device never increases the best bottleneck (the planner
    // can always leave the new device idle). Verified across the
    // registry's shapes, all kernel versions, up to 6 devices.
    let dev = FpgaDevice::u55c();
    for name in ["tiny", "model1", "mnist-deep2", "toy-deep"] {
        let cfg = by_name(name).unwrap();
        for version in KernelVersion::all() {
            let mut prev: Option<f64> = None;
            for n in 1..=6usize {
                let fleet = Fleet::homogeneous(&dev, n);
                match plan_hybrid(&cfg, &fleet, version, 0.10) {
                    Ok(plan) => {
                        let b = plan.bottleneck_s();
                        if let Some(p) = prev {
                            // 1e-8 band: plan_hybrid keeps the incumbent
                            // unless a candidate improves by > 1e-9 rel.
                            assert!(
                                b <= p * (1.0 + 1e-8),
                                "{name}/{}: bottleneck rose {p} -> {b} at {n} devices",
                                version.name()
                            );
                        }
                        prev = Some(b);
                    }
                    Err(e) => assert!(
                        prev.is_none(),
                        "{name}/{}: feasible at {} devices but not {n}: {e:#}",
                        version.name(),
                        n - 1
                    ),
                }
            }
        }
    }
}

#[test]
fn tune_is_deterministic() {
    // No RNG, BTreeMap memoization, fixed generation order: the same
    // inputs must produce byte-identical outcome JSON. (--calibrate is
    // measured and intentionally outside this guarantee.)
    let cfg = by_name("mnist-deep2").unwrap();
    let w = Workload { target_img_s: 100.0, ..Workload::default() };
    let opts = TuneOptions::default();
    let a = tune(&cfg, &w, &opts).unwrap();
    let b = tune(&cfg, &w, &opts).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.spec, b.spec);
}

#[test]
fn tuner_beats_every_pure_strategy_registry_wide() {
    // The CI-gated invariant: the full-fleet single-replica candidate
    // is plan_hybrid's own search space, so the tuner can never fall
    // below pure pipeline, pure shard, or the default hybrid plan.
    for (name, cfg) in registry() {
        let out = tune(&cfg, &Workload::default(), &TuneOptions::default()).unwrap();
        let tp = out.spec.modeled.throughput_img_s;
        for b in &out.baselines {
            if let Some(base) = b.throughput_img_s {
                assert!(
                    tp >= base * (1.0 - 1e-9),
                    "{name}: winner {tp:.0} img/s < {} {base:.0} img/s",
                    b.name
                );
            }
        }
    }
}

#[test]
fn infeasible_budgets_name_the_binding_constraint() {
    let cfg = by_name("model1").unwrap();
    let e = tune(
        &cfg,
        &Workload { power_budget_w: Some(0.5), ..Workload::default() },
        &TuneOptions::default(),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("binding constraint: power budget"), "{e}");

    let e = tune(
        &cfg,
        &Workload { target_img_s: 1e15, ..Workload::default() },
        &TuneOptions::default(),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("binding constraint: target throughput"), "{e}");

    let e = tune(
        &cfg,
        &Workload { p99_ms: Some(1e-9), ..Workload::default() },
        &TuneOptions::default(),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("binding constraint: p99 latency bound"), "{e}");
}

#[test]
fn winning_spec_saves_and_loads_byte_identical() {
    let cfg = by_name("mnist-deep2").unwrap();
    let out = tune(&cfg, &Workload::default(), &TuneOptions::quick()).unwrap();
    let path = std::env::temp_dir().join("bcpnn_tune_roundtrip_spec.json");
    out.spec.save(&path).unwrap();
    let back = DeploymentSpec::load(&path).unwrap();
    assert_eq!(back, out.spec);
    assert_eq!(back.to_json().to_string(), out.spec.to_json().to_string());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn host_spec_serves_with_its_threads_and_precision() {
    // spec -> serve -> report round-trip, host family: the server must
    // actually run with the spec's thread count and weight store.
    let cfg = by_name("tiny").unwrap();
    let out = tune(
        &cfg,
        &Workload::default(),
        &TuneOptions { include_fpga: false, ..TuneOptions::quick() },
    )
    .unwrap();
    let spec = out.spec.clone();
    assert_eq!(spec.backend, BackendKind::Host);
    assert!(spec.threads >= 1 && spec.tile >= 1);

    let (threads, precision) = (spec.threads, spec.precision);
    let cfg_worker = cfg.clone();
    let server = InferenceServer::start(
        move || {
            let mut graph = LayerGraph::new(cfg_worker, 42);
            if precision != QuantFormat::F32 {
                graph.set_precision(precision);
            }
            Ok(GraphBackend::new(graph, threads))
        },
        ServerConfig::default(),
    )
    .unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, 24, 42, 0.15);
    let pending: Vec<_> =
        data.images.iter().map(|img| server.submit(img.clone()).unwrap()).collect();
    for rx in &pending {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 24);
    assert_eq!(rep.threads, spec.threads);
    assert_eq!(rep.precision, spec.precision);
}

#[test]
fn fpga_spec_serves_with_its_replica_topology() {
    // spec -> serve -> report round-trip, FPGA family: the rebuilt
    // per-replica plans drive a ClusterServer with the spec's replica
    // count, and every device the spec names is covered by the slices.
    let cfg = by_name("mnist-deep2").unwrap();
    let out = tune(
        &cfg,
        &Workload::default(),
        &TuneOptions {
            include_host: false,
            fleet: FleetSpec::homogeneous("u55c", 2),
            max_replicas: 2,
            ..TuneOptions::default()
        },
    )
    .unwrap();
    let spec = out.spec.clone();
    assert_eq!(spec.backend, BackendKind::Fpga);
    let fleet_len = spec.fleet.as_ref().unwrap().len();
    assert_eq!(spec.devices_per_replica.iter().sum::<usize>(), fleet_len);

    let plans = plans_for_spec(&spec).unwrap();
    assert_eq!(plans.len(), spec.replicas);
    let modeled: f64 = plans.iter().map(|p| p.throughput_img_s()).sum();
    let rel = (modeled - spec.modeled.throughput_img_s).abs() / modeled;
    assert!(rel < 1e-9, "rebuilt plans model {modeled}, spec says {}", spec.modeled.throughput_img_s);

    let ccfg = ClusterConfig { replicas: spec.replicas, ..ClusterConfig::default() };
    let server =
        ClusterServer::start_hybrid(LayerGraph::new(cfg.clone(), 42), &plans[0], ccfg).unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, 16, 42, 0.15);
    let pending: Vec<_> =
        data.images.iter().map(|img| server.submit(img.clone()).unwrap()).collect();
    for rx in &pending {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 16);
    assert_eq!(rep.replicas.len(), spec.replicas);
}

#[test]
fn tighter_budgets_never_raise_throughput() {
    // Sanity on the objective: adding a constraint can only shrink the
    // feasible set, so the constrained winner cannot out-run the
    // unconstrained one.
    let cfg = by_name("model1").unwrap();
    let opts = TuneOptions::default();
    let free = tune(&cfg, &Workload::default(), &opts).unwrap();
    let capped = tune(
        &cfg,
        &Workload {
            power_budget_w: Some(free.spec.modeled.power_w),
            ..Workload::default()
        },
        &opts,
    )
    .unwrap();
    assert!(
        capped.spec.modeled.throughput_img_s
            <= free.spec.modeled.throughput_img_s * (1.0 + 1e-9),
        "{} vs {}",
        capped.spec.modeled.throughput_img_s,
        free.spec.modeled.throughput_img_s
    );
}
