//! System tests of the unified hybrid placement planner + executor.
//!
//! The load-bearing guarantees:
//!
//! 1. **Bitwise identity, registry-wide**: `HybridExecutor::infer_batch`
//!    equals `LayerGraph::infer` bit for bit on *every* registry
//!    config, whatever placement the planner picks (sharded, chained,
//!    co-located). The shard slices keep the reference accumulation
//!    order; nothing else would survive this pin.
//! 2. **The hybrid plan dominates the legacy planners** on the ROADMAP
//!    bottleneck workload: on `mnist-deep2` the chosen placement has a
//!    strictly lower modeled bottleneck interval than whole-layer
//!    pipeline placement, while pure hypercolumn sharding cannot
//!    express the config at all.
//! 3. Planner edge cases: a 1-HC layer on a many-device fleet, the
//!    equal-split fallback when the balance tolerance is unreachable,
//!    and infeasible mixed fleets erroring with the layer and device
//!    named.

use std::time::Duration;

use bcpnn_accel::bcpnn::LayerGraph;
use bcpnn_accel::cluster::{
    plan, plan_hybrid, plan_pipeline, ClusterConfig, ClusterServer, Fleet, HybridExecutor,
    SchedulePolicy,
};
use bcpnn_accel::config::{by_name, registry, FleetSpec};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn hybrid_executor_bitwise_equals_layer_graph_across_registry() {
    // The acceptance pin: every registry config, served through
    // whatever placement the planner picks on a 3-device fleet, must
    // reproduce the reference inference bit for bit.
    let dev = FpgaDevice::u55c();
    let fleet = Fleet::homogeneous(&dev, 3);
    for (name, cfg) in registry() {
        let graph = LayerGraph::new(cfg.clone(), 42);
        // Big paper models get fewer images so the debug-build test
        // stays fast; the math is per-image, so coverage is unaffected.
        let n_imgs = if cfg.n_in() * cfg.n_h() > 1_000_000 { 2 } else { 6 };
        let d = synth::generate(cfg.img_side, cfg.n_classes, n_imgs, 9, 0.15);
        let reference: Vec<Vec<u32>> =
            d.images.iter().map(|i| bits(&graph.infer(i))).collect();

        let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1)
            .unwrap_or_else(|e| panic!("{name}: no placement: {e:#}"));
        let exec = HybridExecutor::new(graph, &hp).unwrap();
        let probs = exec.infer_batch(&d.images).unwrap();
        assert_eq!(probs.len(), reference.len());
        for (i, (got, want)) in probs.iter().zip(&reference).enumerate() {
            assert_eq!(
                &bits(got), want,
                "{name}: image {i} diverges through the hybrid placement"
            );
        }
    }
}

#[test]
fn tiny_bitwise_identity_across_fleet_sizes() {
    // Same pin across plan shapes: solo, partial shard, full shard.
    let cfg = by_name("tiny").unwrap(); // hc_h = 4
    let dev = FpgaDevice::u55c();
    let graph = LayerGraph::new(cfg.clone(), 7);
    let d = synth::generate(cfg.img_side, cfg.n_classes, 16, 3, 0.15);
    let reference: Vec<Vec<u32>> = d.images.iter().map(|i| bits(&graph.infer(i))).collect();
    for n_dev in [1usize, 2, 3, 4] {
        let fleet = Fleet::homogeneous(&dev, n_dev);
        let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1).unwrap();
        let exec = HybridExecutor::new(graph.clone(), &hp).unwrap();
        let probs = exec.infer_batch(&d.images).unwrap();
        for (i, (got, want)) in probs.iter().zip(&reference).enumerate() {
            assert_eq!(&bits(got), want, "image {i} at {n_dev} devices");
        }
    }
}

#[test]
fn mnist_deep2_hybrid_strictly_beats_both_legacy_planners() {
    // ROADMAP's hybrid-parallelism acceptance: with one spare device
    // the planner shards the bottleneck stage, strictly lowering the
    // modeled bottleneck vs plan_pipeline, while plan() cannot express
    // the stacked config at all (no legal single-layer plan exists).
    let cfg = by_name("mnist-deep2").unwrap();
    let dev = FpgaDevice::u55c();
    let pipe = plan_pipeline(&cfg, KernelVersion::Infer, &dev).unwrap();
    let hybrid =
        plan_hybrid(&cfg, &Fleet::homogeneous(&dev, 3), KernelVersion::Infer, 0.1).unwrap();
    assert!(
        hybrid.bottleneck_s() < pipe.bottleneck().kernel_s,
        "hybrid bottleneck {} must be strictly below pipeline {}",
        hybrid.bottleneck_s(),
        pipe.bottleneck().kernel_s
    );
    assert!(hybrid.stages.iter().any(|st| st.sharded()));
    // And the modeled throughput dominates the best pure strategy.
    assert!(hybrid.throughput_img_s() > pipe.throughput_img_s());
    let err = plan(&cfg, 3, KernelVersion::Infer, &dev).unwrap_err().to_string();
    assert!(err.contains("plan_hybrid"), "{err}");
}

#[test]
fn one_hc_layer_on_many_devices_clamps_and_serves() {
    // Planner edge: a layer with a single hypercolumn cannot shard
    // below the softmax floor — the plan uses one device, idles the
    // rest, and still serves bit-identically.
    let mut cfg = by_name("tiny").unwrap();
    cfg.hc_h = 1;
    cfg.mc_h = 16;
    cfg.validate().unwrap();
    let fleet = Fleet::homogeneous(&FpgaDevice::u55c(), 4);
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1).unwrap();
    assert_eq!(hp.stages[0].pieces.len(), 1);
    assert_eq!(hp.idle_devices.len(), 3);

    let graph = LayerGraph::new(cfg.clone(), 5);
    let d = synth::generate(cfg.img_side, cfg.n_classes, 8, 1, 0.15);
    let reference: Vec<Vec<u32>> = d.images.iter().map(|i| bits(&graph.infer(i))).collect();
    let exec = HybridExecutor::new(graph, &hp).unwrap();
    for (got, want) in exec.infer_batch(&d.images).unwrap().iter().zip(&reference) {
        assert_eq!(&bits(got), want);
    }
}

#[test]
fn unreachable_tolerance_reports_equal_split_fallback() {
    // 3 HCs across 2 devices: skew ~2 whichever boundary is chosen,
    // so a 5% tolerance is unreachable and the planner must fall back
    // to the predictable equal split and flag it.
    let mut cfg = by_name("tiny").unwrap();
    cfg.hc_h = 3;
    cfg.validate().unwrap();
    let fleet = Fleet::homogeneous(&FpgaDevice::u55c(), 2);
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.05).unwrap();
    let st = &hp.stages[0];
    assert!(!st.balanced);
    assert_eq!(
        st.pieces.iter().map(|p| p.hc_hi - p.hc_lo).collect::<Vec<_>>(),
        vec![2, 1]
    );
}

#[test]
fn infeasible_mixed_fleet_names_layer_and_device() {
    // Per-shard BRAM blows past the routability ceiling on both device
    // models of the fleet: the error must say which layer on which
    // device, not just "no".
    let mut cfg = by_name("small").unwrap();
    cfg.name = "hybrid-huge".into();
    cfg.hc_h = 32;
    cfg.mc_h = 2048; // n_h = 65536
    cfg.validate().unwrap();
    let fleet = Fleet { devices: vec![FpgaDevice::u55c(), FpgaDevice::u280()] };
    let err = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("layer 0"), "{err}");
    assert!(err.contains("Alveo"), "{err}");
}

#[test]
fn fleet_spec_resolves_to_mixed_fleet_plan() {
    // The config-level fleet spec drives a real mixed-device plan.
    let spec = FleetSpec::parse("u55c,u280").unwrap();
    let fleet = Fleet::resolve(&spec).unwrap();
    assert_eq!(fleet.len(), 2);
    let cfg = by_name("model2").unwrap();
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.25).unwrap();
    assert_eq!(hp.n_devices_used(), 2);
    let names: Vec<&str> = hp
        .stages
        .iter()
        .flat_map(|st| st.pieces.iter().map(|p| hp.fleet[p.device_index].name.as_str()))
        .collect();
    assert!(names.contains(&"Alveo U280"), "{names:?}");
}

#[test]
fn hybrid_cluster_serves_stacked_config_with_failover() {
    // The serving story end to end: a stacked config behind the
    // cluster coordinator on a hybrid plan, surviving a replica kill
    // without losing requests.
    let cfg = by_name("toy-deep").unwrap();
    let fleet = Fleet::homogeneous(&FpgaDevice::u55c(), 3);
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1).unwrap();
    let graph = LayerGraph::new(cfg.clone(), 42);
    let server = ClusterServer::start_hybrid(
        graph,
        &hp,
        ClusterConfig {
            replicas: 2,
            // Ignored by start_hybrid (topology comes from the plan).
            shards_per_replica: hp.n_devices_used(),
            queue_depth: 128,
            flush_timeout: Duration::from_millis(2),
            policy: SchedulePolicy::LeastOutstanding,
        },
    )
    .unwrap();

    let d = synth::generate(cfg.img_side, cfg.n_classes, 24, 5, 0.15);
    // Warm traffic on both replicas.
    let warm: Vec<_> = d.images[..8]
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &warm {
        let probs = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(probs.len(), cfg.n_out());
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
    // Kill one replica; the rest of the stream must still drain.
    assert!(server.fail_replica(0));
    assert_eq!(server.healthy_replicas(), 1);
    let tail: Vec<_> = d.images[8..]
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &tail {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 24, "no request may be lost");
    assert!(rep.replicas[0].failed);
    assert!(!rep.replicas[1].failed);
    // Worker reports carry the (stage, shard) topology of the plan:
    // one worker per shard of a sharded stage, one per co-located
    // stage.
    let workers: usize = hp
        .stages
        .iter()
        .map(|st| if st.sharded() { st.pieces.len() } else { 1 })
        .sum();
    assert_eq!(rep.replicas[1].shards.len(), workers);
}
