//! Integration tests over the PJRT runtime: the AOT artifacts (JAX +
//! Pallas, lowered to HLO text) executed from rust must agree with the
//! pure-rust reference network on identical parameters and data.
//!
//! Requires `make artifacts` (the repo's build flow runs it first).

use std::path::{Path, PathBuf};
use std::time::Duration;

use bcpnn_accel::bcpnn::network::argmax;
use bcpnn_accel::bcpnn::Network;
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::driver::batches;
use bcpnn_accel::coordinator::{Driver, InferenceServer, ServerConfig, TrainOptions};
use bcpnn_accel::data::synth;
use bcpnn_accel::runtime::{Manifest, Session};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// PJRT tests need AOT artifacts built by the python toolchain; skip
/// cleanly where they are absent (e.g. offline CI) instead of failing —
/// same gating as `manifest::tests::real_manifest_if_built`.
macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/manifest.json not built");
            return;
        }
    };
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn manifest_covers_default_configs() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    for cfg in ["tiny", "small", "edge"] {
        for mode in ["infer", "train_unsup", "train_sup"] {
            let a = m.get(cfg, mode).unwrap();
            assert!(a.file.exists(), "{:?}", a.file);
        }
    }
}

#[test]
fn infer_artifact_matches_rust_reference() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session = Session::load_modes(&artifacts_dir(), "tiny", &["infer"]).unwrap();
    let driver = Driver::new(session, "tiny", 7).unwrap();

    // Mirror the driver's params into the pure-rust network.
    let mut net = Network::new(cfg.clone(), 7);
    net.params = driver.params.clone();
    net.refresh_mask();

    let d = synth::generate(cfg.img_side, cfg.n_classes, cfg.batch, 3, 0.15);
    let probs = driver.infer_batch(&d.images).unwrap();
    assert_eq!(probs.len(), cfg.batch);
    for (img, p_jax) in d.images.iter().zip(&probs) {
        let p_rust = net.infer(img);
        let diff = max_abs_diff(p_jax, &p_rust);
        assert!(diff < 1e-4, "probs diverge: {diff}");
        assert!((p_jax.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
}

#[test]
fn train_unsup_artifact_matches_rust_reference() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session = Session::load_modes(&artifacts_dir(), "tiny", &["train_unsup"]).unwrap();
    let mut driver = Driver::new(session, "tiny", 11).unwrap();

    let mut net = Network::new(cfg.clone(), 11);
    net.params = driver.params.clone();
    net.refresh_mask();

    let d = synth::generate(cfg.img_side, cfg.n_classes, cfg.batch, 5, 0.15);
    driver.unsup_batch(&d.images).unwrap();
    for img in &d.images {
        net.train_unsup_step(img);
    }
    assert!(max_abs_diff(&driver.params.pi, &net.params.pi) < 1e-5, "pi");
    assert!(max_abs_diff(&driver.params.pj, &net.params.pj) < 1e-5, "pj");
    assert!(max_abs_diff(&driver.params.pij, &net.params.pij) < 1e-5, "pij");
    // Weights go through log(): slightly looser. Compare under the
    // mask: the device kernel maintains every synapse densely while
    // the block-sparse host path re-derives masked-out weights only
    // on (re)activation — both agree wherever support can read them.
    let mask = net.params.expand_mask(&cfg);
    let wij_diff = driver
        .params
        .wij
        .iter()
        .zip(&net.params.wij)
        .zip(&mask)
        .filter(|(_, &m)| m != 0.0)
        .map(|((a, b), _)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(wij_diff < 1e-3, "wij (masked): {wij_diff}");
    assert!(max_abs_diff(&driver.params.bj, &net.params.bj) < 1e-4, "bj");
}

#[test]
fn train_sup_artifact_matches_rust_reference() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session =
        Session::load_modes(&artifacts_dir(), "tiny", &["train_sup"]).unwrap();
    let mut driver = Driver::new(session, "tiny", 13).unwrap();

    let mut net = Network::new(cfg.clone(), 13);
    net.params = driver.params.clone();
    net.refresh_mask();

    let d = synth::generate(cfg.img_side, cfg.n_classes, cfg.batch, 9, 0.15);
    driver.sup_batch(&d.images, &d.labels).unwrap();
    for (img, &l) in d.images.iter().zip(&d.labels) {
        net.train_sup_step(img, l as usize);
    }
    assert!(max_abs_diff(&driver.params.qik, &net.params.qik) < 1e-5, "qik");
    assert!(max_abs_diff(&driver.params.who, &net.params.who) < 1e-3, "who");
    assert!(max_abs_diff(&driver.params.bk, &net.params.bk) < 1e-4, "bk");
}

#[test]
fn driver_end_to_end_learning_beats_chance() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session = Session::load(&artifacts_dir(), "tiny").unwrap();
    let mut driver = Driver::new(session, "tiny", 42).unwrap();

    let data = synth::generate(cfg.img_side, cfg.n_classes, 192, 11, 0.15);
    let (train, test) = data.split(128);
    let out = driver
        .train(&train, &test, &TrainOptions { epochs: 2, ..Default::default() })
        .unwrap();
    let chance = 1.0 / cfg.n_classes as f64;
    assert!(
        out.test_acc > chance + 0.15,
        "test acc {} vs chance {chance}",
        out.test_acc
    );
    assert!(out.unsup.count > 0 && out.infer.count > 0);
}

#[test]
fn driver_with_structural_plasticity_trains() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session = Session::load(&artifacts_dir(), "tiny").unwrap();
    let mut driver = Driver::new(session, "tiny", 21).unwrap();

    let data = synth::generate(cfg.img_side, cfg.n_classes, 192, 17, 0.15);
    let (train, test) = data.split(128);
    let out = driver
        .train(
            &train,
            &test,
            &TrainOptions {
                epochs: 2,
                structural: true,
                struct_interval: 2,
                seed: 21,
                threads: 1,
            },
        )
        .unwrap();
    assert!(out.rewire_passes > 0, "structural plasticity never ran");
    // Mask column sparsity preserved through rewiring + device roundtrips.
    for h in 0..cfg.hc_h {
        let active: f32 = (0..cfg.hc_in())
            .map(|i| driver.params.mask_hc[i * cfg.hc_h + h])
            .sum();
        assert_eq!(active as usize, cfg.nact_hi);
    }
    let chance = 1.0 / cfg.n_classes as f64;
    assert!(out.test_acc > chance, "struct run below chance");
}

#[test]
fn inference_server_serves_batched_requests() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let dir = artifacts_dir();
    let server = InferenceServer::start(
        move || {
            let session = Session::load_modes(&dir, "tiny", &["infer"])?;
            Driver::new(session, "tiny", 1)
        },
        ServerConfig {
            queue_depth: 64,
            flush_timeout: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let d = synth::generate(cfg.img_side, cfg.n_classes, 100, 3, 0.15);
    let handles: Vec<_> = d
        .images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &handles {
        let probs = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(probs.len(), cfg.n_out());
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!(argmax(&probs) < cfg.n_out());
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 100);
    assert!(rep.batches >= (100 / cfg.batch) as u64);
    assert!(rep.mean_fill > 1.0, "no batching happened: {}", rep.mean_fill);
    assert!(rep.latency.p99_ms >= rep.latency.p50_ms);
}

#[test]
fn checkpoint_roundtrip_preserves_accuracy() {
    // The deployment flow: train -> save -> load into a fresh driver ->
    // identical predictions.
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session = Session::load(&artifacts_dir(), "tiny").unwrap();
    let mut driver = Driver::new(session, "tiny", 31).unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, 192, 33, 0.15);
    let (train, test) = data.split(128);
    driver
        .train(&train, &test, &TrainOptions { epochs: 1, ..Default::default() })
        .unwrap();
    let acc_before = driver.evaluate(&test).unwrap();

    let path = std::env::temp_dir().join(format!("bcpnn_it_{}.ckpt", std::process::id()));
    bcpnn_accel::bcpnn::checkpoint::save(&path, &cfg, &driver.params).unwrap();
    let (loaded_cfg, params) = bcpnn_accel::bcpnn::checkpoint::load(&path).unwrap();
    assert_eq!(loaded_cfg.name, "tiny");

    let session2 = Session::load_modes(&artifacts_dir(), "tiny", &["infer"]).unwrap();
    let mut fresh = Driver::new(session2, "tiny", 999).unwrap();
    fresh.set_params(params);
    let acc_after = fresh.evaluate(&test).unwrap();
    assert!((acc_after - acc_before).abs() < 1e-9,
            "accuracy changed across checkpoint: {acc_before} -> {acc_after}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn server_startup_failure_reported() {
    let err = InferenceServer::start(
        || -> anyhow::Result<Driver> { anyhow::bail!("boom") },
        ServerConfig::default(),
    )
    .err()
    .map(|e| e.to_string())
    .unwrap_or_default();
    assert!(err.contains("boom"), "{err}");
}

#[test]
fn batches_helper_and_driver_eval_agree() {
    require_artifacts!();
    let cfg = by_name("tiny").unwrap();
    let session = Session::load_modes(&artifacts_dir(), "tiny", &["infer"]).unwrap();
    let driver = Driver::new(session, "tiny", 5).unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 50, 5, 0.15);
    // evaluate() must handle the short remainder batch (50 = 3*16 + 2).
    let acc = driver.evaluate(&d).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    let covered: usize = batches(&d, cfg.batch).map(|(i, _)| i.len()).sum();
    assert_eq!(covered, 50);
}
