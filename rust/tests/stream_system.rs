//! System tests of the stream dataflow runtime driving real BCPNN
//! stage functions (no PJRT needed): the software analogue of running
//! the HLS kernel through its pipeline — the ablation substrate behind
//! `benches/ablation_dataflow.rs`.

use std::sync::Arc;

use bcpnn_accel::bcpnn::Network;
use bcpnn_accel::config::by_name;
use bcpnn_accel::data::encode::encode_image;
use bcpnn_accel::data::synth;
use bcpnn_accel::stream::pipeline::{run_sequential, Pipeline};
use bcpnn_accel::stream::depth::{minimal_depths, simulate, StageSpec};

/// Item flowing through the BCPNN inference pipeline.
#[derive(Debug, Clone)]
struct Flow {
    x: Vec<f32>,
    support: Vec<f32>,
    probs: Vec<f32>,
}

fn stage_fns(net: Arc<Network>) -> (
    impl FnMut(Vec<f32>) -> Flow + Send,
    impl FnMut(Flow) -> Flow + Send,
    impl FnMut(Flow) -> Flow + Send,
) {
    let n1 = net.clone();
    let n2 = net.clone();
    let encode = move |img: Vec<f32>| Flow {
        x: encode_image(&img),
        support: Vec::new(),
        probs: Vec::new(),
    };
    let support = move |mut f: Flow| {
        f.support = n1.support(&f.x);
        f
    };
    let act = move |mut f: Flow| {
        let mut s = f.support.clone();
        Network::hc_softmax(&mut s, n2.cfg.hc_h, n2.cfg.mc_h, n2.cfg.gain);
        f.probs = n2.output_activity(&s);
        f
    };
    (encode, support, act)
}

#[test]
fn pipelined_inference_matches_direct() {
    let cfg = by_name("tiny").unwrap();
    let net = Arc::new(Network::new(cfg.clone(), 3));
    let d = synth::generate(cfg.img_side, cfg.n_classes, 64, 5, 0.15);

    let (encode, support, act) = stage_fns(net.clone());
    let (out, rep) = Pipeline::source("images", 8, d.images.clone())
        .stage("encode", 8, encode)
        .stage("support", 8, support)
        .stage("activate", 8, act)
        .collect();
    assert_eq!(out.len(), 64);
    assert_eq!(rep.items, 64);

    for (flow, img) in out.iter().zip(&d.images) {
        let direct = net.infer(img);
        let diff: f32 = flow
            .probs
            .iter()
            .zip(&direct)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-6, "pipeline diverges from direct: {diff}");
    }
}

#[test]
fn pipeline_reports_stage_utilization() {
    let cfg = by_name("tiny").unwrap();
    let net = Arc::new(Network::new(cfg.clone(), 4));
    let d = synth::generate(cfg.img_side, cfg.n_classes, 128, 6, 0.15);
    let (encode, support, act) = stage_fns(net);
    let (_, rep) = Pipeline::source("images", 16, d.images)
        .stage("encode", 16, encode)
        .stage("support", 16, support)
        .stage("activate", 16, act)
        .collect();
    // The masked mat-vec dominates -> "support" should be the
    // bottleneck stage, mirroring the accelerator's datapath.
    let b = rep.bottleneck().unwrap();
    assert_eq!(b.name, "support", "bottleneck was {}", b.name);
    for s in &rep.stages {
        assert!(s.utilization() <= 1.0 + 1e-9);
    }
}

/// Balanced 4-stage inference pipeline: the support mat-vec is split
/// across two stages (hidden columns halved), the way the FPGA splits
/// the datapath across HBM channel groups.
fn balanced_stages(
    net: Arc<Network>,
) -> (
    impl FnMut(Vec<f32>) -> Flow + Send,
    impl FnMut(Flow) -> Flow + Send,
    impl FnMut(Flow) -> Flow + Send,
    impl FnMut(Flow) -> Flow + Send,
) {
    let half = net.cfg.n_h() / 2;
    let n1 = net.clone();
    let n2 = net.clone();
    let n3 = net.clone();
    let encode = move |img: Vec<f32>| Flow {
        x: encode_image(&img),
        support: Vec::new(),
        probs: Vec::new(),
    };
    let support_lo = move |mut f: Flow| {
        f.support = n1.support_cols(&f.x, 0, half);
        f
    };
    let support_hi = move |mut f: Flow| {
        let hi = n2.support_cols(&f.x, half, n2.cfg.n_h());
        f.support.extend_from_slice(&hi);
        f
    };
    let act = move |mut f: Flow| {
        let mut s = f.support.clone();
        Network::hc_softmax(&mut s, n3.cfg.hc_h, n3.cfg.mc_h, n3.cfg.gain);
        f.probs = n3.output_activity(&s);
        f
    };
    (encode, support_lo, support_hi, act)
}

#[test]
fn split_support_pipeline_matches_direct() {
    let cfg = by_name("tiny").unwrap();
    let net = Arc::new(Network::new(cfg.clone(), 9));
    let d = synth::generate(cfg.img_side, cfg.n_classes, 32, 9, 0.15);
    let (e, s1, s2, a) = balanced_stages(net.clone());
    let (out, _) = Pipeline::source("images", 8, d.images.clone())
        .stage("encode", 8, e)
        .stage("support_lo", 8, s1)
        .stage("support_hi", 8, s2)
        .stage("activate", 8, a)
        .collect();
    for (flow, img) in out.iter().zip(&d.images) {
        let direct = net.infer(img);
        let diff: f32 = flow
            .probs
            .iter()
            .zip(&direct)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-5, "split pipeline diverges: {diff}");
    }
}

/// Packetized stage functions: each pipeline item is a *packet* of
/// images (the FPGA streams packets, not scalars), which amortizes
/// FIFO overhead exactly as the hardware does.
fn packet_stages(
    net: Arc<Network>,
) -> (
    impl FnMut(Vec<Vec<f32>>) -> Vec<Flow> + Send,
    impl FnMut(Vec<Flow>) -> Vec<Flow> + Send,
    impl FnMut(Vec<Flow>) -> Vec<Flow> + Send,
    impl FnMut(Vec<Flow>) -> Vec<Flow> + Send,
) {
    let half = net.cfg.n_h() / 2;
    let n1 = net.clone();
    let n2 = net.clone();
    let n3 = net.clone();
    let encode = move |imgs: Vec<Vec<f32>>| {
        imgs.into_iter()
            .map(|img| Flow { x: encode_image(&img), support: Vec::new(), probs: Vec::new() })
            .collect()
    };
    let support_lo = move |mut fs: Vec<Flow>| {
        for f in fs.iter_mut() {
            f.support = n1.support_cols(&f.x, 0, half);
        }
        fs
    };
    let support_hi = move |mut fs: Vec<Flow>| {
        for f in fs.iter_mut() {
            let hi = n2.support_cols(&f.x, half, n2.cfg.n_h());
            f.support.extend_from_slice(&hi);
        }
        fs
    };
    let act = move |mut fs: Vec<Flow>| {
        for f in fs.iter_mut() {
            let mut s = f.support.clone();
            Network::hc_softmax(&mut s, n3.cfg.hc_h, n3.cfg.mc_h, n3.cfg.gain);
            f.probs = n3.output_activity(&s);
        }
        fs
    };
    (encode, support_lo, support_hi, act)
}

#[test]
fn packetized_pipeline_matches_direct() {
    // Functional check of the packet pipeline (this host has a single
    // CPU core, so wall-clock dataflow gains are measured with the
    // cycle-level simulator below, not threads).
    let cfg = by_name("edge").unwrap();
    let net = Arc::new(Network::new(cfg.clone(), 5));
    let d = synth::generate(cfg.img_side, cfg.n_classes, 64, 7, 0.15);
    let packets: Vec<Vec<Vec<f32>>> =
        d.images.chunks(16).map(|c| c.to_vec()).collect();
    let (e, s1, s2, a) = packet_stages(net.clone());
    let (out, _) = Pipeline::source("packets", 8, packets)
        .stage("encode", 8, e)
        .stage("support_lo", 8, s1)
        .stage("support_hi", 8, s2)
        .stage("activate", 8, a)
        .collect();
    let flows: Vec<&Flow> = out.iter().flatten().collect();
    assert_eq!(flows.len(), 64);
    for (flow, img) in flows.iter().zip(&d.images) {
        let direct = net.infer(img);
        let diff: f32 = flow
            .probs
            .iter()
            .zip(&direct)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-5, "packet pipeline diverges: {diff}");
    }
}

#[test]
fn dataflow_beats_sequential_in_cycle_simulation() {
    // Fig. 3's ablation (the paper's "~70% performance improvement"
    // from dataflow): on the cycle-level model of the kernel chain,
    // dataflow throughput = bottleneck stage, while the sequential
    // design pays the *sum* of all stages per item. This host has one
    // CPU core, so the claim is validated in simulated cycles (the
    // correct currency for an FPGA claim anyway).
    for name in ["model1", "model2", "model3"] {
        let cfg = by_name(name).unwrap();
        let stages = vec![
            StageSpec::streaming("hbm_read", 1),
            StageSpec::streaming("support", 1),
            StageSpec::with_barrier("softmax", 1, cfg.mc_h.div_ceil(16) as u64),
            StageSpec::streaming("plasticity", 1),
            StageSpec::streaming("hbm_write", 1),
        ];
        let items = 2048u64;
        // Sequential (Fig. 3 left): each item traverses every stage
        // before the next enters; cost = sum of stage service times.
        let seq_cycles: u64 =
            items * stages.iter().map(|s| s.cycles_per_item).sum::<u64>();
        // Dataflow (Fig. 3 right): sized FIFOs, overlapped stages.
        let depths = minimal_depths(&stages, items, 0.05);
        let df = simulate(&stages, &depths, items);
        assert!(!df.deadlock);
        let improvement = seq_cycles as f64 / df.total_cycles as f64;
        assert!(
            improvement > 1.7,
            "{name}: dataflow improvement only {improvement:.2}x \
             (paper reports ~70%: >=1.7x)"
        );
    }
}

#[test]
fn run_sequential_matches_pipeline_output_order() {
    let items: Vec<i64> = (0..50).collect();
    let rep = run_sequential(
        items.clone(),
        vec![
            ("x2", Box::new(|v: i64| v * 2) as Box<dyn FnMut(i64) -> i64>),
            ("plus1", Box::new(|v: i64| v + 1)),
        ],
    );
    assert_eq!(rep.items, 50);
    let (out, _) = Pipeline::source("src", 4, items)
        .stage("x2", 4, |v: i64| v * 2)
        .stage("plus1", 4, |v: i64| v + 1)
        .collect();
    assert_eq!(out, (0..50).map(|v| v * 2 + 1).collect::<Vec<_>>());
}

#[test]
fn kernel_chain_depth_analysis_deadlock_free() {
    // The depth-analysis path used by `repro fifo-depths` for every
    // built-in config: sized depths must be deadlock-free and within
    // 10% of unbounded throughput.
    for name in ["tiny", "small", "edge", "model1"] {
        let cfg = by_name(name).unwrap();
        let stages = vec![
            StageSpec::streaming("hbm_read", 1),
            StageSpec::streaming("support", 1),
            StageSpec::with_barrier("softmax", 1, cfg.mc_h.div_ceil(16) as u64),
            StageSpec::streaming("plasticity", 1),
            StageSpec::streaming("hbm_write", 1),
        ];
        let n = 512u64;
        let depths = minimal_depths(&stages, n, 0.05);
        let sized = simulate(&stages, &depths, n);
        assert!(!sized.deadlock, "{name}: deadlock at sized depths");
        let unbounded = simulate(&stages, &[4096, 4096, 4096, 4096], n);
        assert!(
            (sized.total_cycles as f64) <= unbounded.total_cycles as f64 * 1.10,
            "{name}: sized {} vs unbounded {}",
            sized.total_cycles,
            unbounded.total_cycles
        );
    }
}
