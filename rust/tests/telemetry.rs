//! Telemetry integration tests: the decomposition contract end to end.
//!
//! - `serve.*`: per-request end-to-end latency must equal queue wait +
//!   service (within scheduler slack) — pinned with a sleeping mock
//!   backend so the components are macroscopic.
//! - hybrid spans: on a pure-pipeline plan, the per-stage queue-wait +
//!   service spans (plus the final result-stream hop) must sum to the
//!   measured per-round latency within tolerance.
//! - instrumented FIFOs under real multi-producer contention: gauges
//!   and stats stay consistent (depth returns to 0, high water bounded
//!   by capacity, pushes == pops).

use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use bcpnn_accel::bcpnn::LayerGraph;
use bcpnn_accel::cluster::{plan_pipeline, PipelineParallelExecutor};
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::{InferBackend, InferenceServer, ServerConfig};
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::stream::Fifo;
use bcpnn_accel::telemetry::MetricsRegistry;
use bcpnn_accel::util::json::Json;

/// Backend that sleeps a fixed, macroscopic time per batch so the
/// service component of the decomposition is unmistakable.
#[derive(Clone)]
struct SleepBackend {
    batch: usize,
    sleep: Duration,
    calls: Arc<Mutex<u64>>,
}

impl InferBackend for SleepBackend {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer_batch(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        *self.calls.lock().unwrap() += 1;
        thread::sleep(self.sleep);
        Ok(images.iter().map(|img| vec![img[0]]).collect())
    }
}

#[test]
fn serve_decomposition_sums_to_e2e() {
    let sleep_ms = 15.0;
    let backend = SleepBackend {
        batch: 4,
        sleep: Duration::from_millis(sleep_ms as u64),
        calls: Arc::new(Mutex::new(0)),
    };
    let server = InferenceServer::start(
        move || Ok(backend),
        ServerConfig {
            queue_depth: 64,
            flush_timeout: Duration::from_millis(5),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let n = 16usize;
    let pending: Vec<_> = (0..n).map(|i| server.submit(vec![i as f32]).unwrap()).collect();
    for rx in &pending {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }

    // Registry state while the server is still up: counters named per
    // the serve.* scheme, all requests accounted for.
    let reg = server.metrics();
    assert_eq!(reg.counter("serve.requests").get(), n as u64);
    assert_eq!(reg.counter("serve.served").get(), n as u64);
    assert!(reg.counter("serve.batches").get() >= (n / 4) as u64);
    assert_eq!(reg.counter("serve.backend_errors").get(), 0);
    let names = reg.names();
    for want in [
        "serve.queue.depth",
        "serve.queue.high_water",
        "serve.queue.capacity",
        "serve.e2e_us",
        "serve.queue_wait_us",
        "serve.service_us",
    ] {
        assert!(names.iter().any(|x| x == want), "missing {want} in {names:?}");
    }

    let rep = server.shutdown();
    assert_eq!(rep.served, n as u64);
    assert_eq!(rep.latency.count, n);
    assert_eq!(rep.queue_wait.count, n);
    assert_eq!(rep.service.count, n);

    // The sleep dominates service time and is visible in it.
    assert!(
        rep.service.mean_ms >= 0.6 * sleep_ms,
        "service mean {:.3} ms should carry the {sleep_ms} ms sleep",
        rep.service.mean_ms
    );
    // Decomposition contract: e2e = queue wait + service per request
    // (slack: scheduler noise, response-channel overhead, histogram
    // quantization <= 1/32 relative).
    let sum = rep.queue_wait.mean_ms + rep.service.mean_ms;
    let gap = (rep.latency.mean_ms - sum).abs();
    assert!(
        gap <= 0.3 * rep.latency.mean_ms + 2.0,
        "e2e mean {:.3} ms vs wait+service {:.3} ms (gap {:.3})",
        rep.latency.mean_ms,
        sum,
        gap
    );
    // Percentile ordering holds through the bounded histogram.
    assert!(rep.latency.p50_ms <= rep.latency.p99_ms);
    assert!(rep.latency.p99_ms <= rep.latency.p999_ms);
    assert!(rep.latency.p999_ms <= rep.latency.max_ms + 1e-9);

    // The machine-readable form round-trips with the p999 field.
    let j = Json::parse(&rep.to_json().to_string()).unwrap();
    let p999 = j
        .req("latency")
        .unwrap()
        .req("p999_ms")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!((p999 - rep.latency.p999_ms).abs() < 1e-6);
}

#[test]
fn hybrid_pipeline_spans_sum_to_round_latency() {
    // Pure pipeline (one worker per stage, no shard fan-out, no merge
    // plumbing) on a stacked config: per round, the critical path is
    // exactly stage0 wait + stage0 service + stage1 wait + ... +
    // result-stream wait, so the span means must sum to the measured
    // round latency within tolerance.
    let cfg = by_name("toy-deep").unwrap();
    let pplan = plan_pipeline(&cfg, KernelVersion::Infer, &FpgaDevice::u55c()).unwrap();
    let n_stages = pplan.stages.len();
    let exec =
        PipelineParallelExecutor::new(LayerGraph::new(cfg.clone(), 42), &pplan).unwrap();

    // Single-image rounds: one tile in flight, no pipelining overlap.
    let img = vec![0.5; cfg.hc_in()];
    let rounds = 32usize;
    for _ in 0..rounds {
        exec.infer_batch(std::slice::from_ref(&img)).unwrap();
    }

    let reg = exec.metrics();
    let e2e = reg.histogram("infer_us").stats();
    assert_eq!(e2e.count, rounds);
    let result_wait = reg.histogram("result.queue_wait_us").stats();
    assert_eq!(result_wait.count, rounds);

    let mut sum_ms = result_wait.mean_ms;
    for si in 0..n_stages {
        let wait = reg.histogram(&format!("stage{si}.shard0.queue_wait_us")).stats();
        let svc = reg.histogram(&format!("stage{si}.shard0.service_us")).stats();
        assert_eq!(wait.count, rounds, "stage {si} wait");
        assert_eq!(svc.count, rounds, "stage {si} service");
        sum_ms += wait.mean_ms + svc.mean_ms;
    }
    let gap = (e2e.mean_ms - sum_ms).abs();
    assert!(
        gap <= 0.5 * e2e.mean_ms + 0.3,
        "per-stage spans ({sum_ms:.4} ms) should sum to round latency \
         ({:.4} ms) within tolerance",
        e2e.mean_ms
    );

    // Shutdown reports carry the same span stats per stage.
    let reports = exec.shutdown();
    assert_eq!(reports.len(), n_stages);
    for r in &reports {
        assert_eq!(r.queue_wait.count, rounds);
        assert_eq!(r.service.count, rounds);
    }
}

#[test]
fn instrumented_fifo_consistent_under_contention() {
    let reg = MetricsRegistry::new_arc();
    let f: Fifo<u64> = Fifo::with_capacity(8);
    f.instrument(&reg, "contended");

    let producers = 4u64;
    let per_producer = 250u64;
    let consumers = 2usize;

    let mut handles = Vec::new();
    for p in 0..producers {
        let tx = f.clone();
        handles.push(thread::spawn(move || {
            for i in 0..per_producer {
                tx.send(p * per_producer + i).unwrap();
            }
        }));
    }
    let mut drains = Vec::new();
    for _ in 0..consumers {
        let rx = f.clone();
        drains.push(thread::spawn(move || {
            let mut got = 0u64;
            while rx.recv().is_ok() {
                got += 1;
            }
            got
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    f.close();
    let total: u64 = drains.into_iter().map(|d| d.join().unwrap()).sum();
    assert_eq!(total, producers * per_producer);

    let s = f.stats();
    assert_eq!(s.pushes, producers * per_producer);
    assert_eq!(s.pops, producers * per_producer);
    assert!(s.high_water >= 1 && s.high_water <= 8, "high water {}", s.high_water);

    // Gauges mirror the stream: empty at rest, high water bounded by
    // capacity and matching the stats counter.
    assert_eq!(reg.gauge("contended.depth").get(), 0);
    assert_eq!(reg.gauge("contended.capacity").get(), 8);
    assert_eq!(reg.gauge("contended.high_water").get(), s.high_water as i64);
}
