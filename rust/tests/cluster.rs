//! System tests of the `cluster/` scale-out subsystem: sharded
//! inference must be bitwise identical to the single-device reference,
//! the generic serving layer must drive a sharded backend, and the
//! cluster coordinator must spread load and survive replica failure
//! without dropping requests.

use std::time::Duration;

use bcpnn_accel::bcpnn::Network;
use bcpnn_accel::cluster::{
    plan, ClusterConfig, ClusterServer, SchedulePolicy, ShardedExecutor,
};
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::{InferenceServer, ServerConfig};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};

/// A reference network with non-trivial (trained) weights.
fn trained_net(seed: u64) -> Network {
    let cfg = by_name("tiny").unwrap();
    let mut net = Network::new(cfg.clone(), seed);
    let d = synth::generate(cfg.img_side, cfg.n_classes, 48, seed, 0.15);
    for img in &d.images {
        net.train_unsup_step(img);
    }
    for (img, &l) in d.images.iter().zip(&d.labels) {
        net.train_sup_step(img, l as usize);
    }
    net
}

#[test]
fn sharded_inference_bitwise_equals_single_device_reference() {
    let net = trained_net(42);
    let cfg = net.cfg.clone();
    let dev = FpgaDevice::u55c();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 24, 9, 0.15);
    let reference: Vec<Vec<f32>> = d.images.iter().map(|img| net.infer(img)).collect();

    for n_shards in 1..=cfg.hc_h {
        let p = plan(&cfg, n_shards, KernelVersion::Infer, &dev).unwrap();
        let exec = ShardedExecutor::new(net.clone(), &p).unwrap();
        let probs = exec.infer_batch(&d.images).unwrap();
        assert_eq!(probs.len(), reference.len());
        for (i, (got, want)) in probs.iter().zip(&reference).enumerate() {
            // Bitwise: the shard slices use the reference accumulation
            // order, so not even the last ulp may differ.
            let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                got_bits, want_bits,
                "image {i} diverges at {n_shards} shards: {got:?} vs {want:?}"
            );
        }
    }
}

#[test]
fn uneven_shard_counts_still_exact() {
    // hc_h = 4 split 3 ways -> shards of 2/1/1 hypercolumns.
    let net = trained_net(7);
    let cfg = net.cfg.clone();
    let p = plan(&cfg, 3, KernelVersion::Infer, &FpgaDevice::u55c()).unwrap();
    assert_eq!(p.skew(), 2.0);
    let exec = ShardedExecutor::new(net.clone(), &p).unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 10, 3, 0.15);
    let probs = exec.infer_batch(&d.images).unwrap();
    for (img, got) in d.images.iter().zip(&probs) {
        assert_eq!(got, &net.infer(img));
    }
}

#[test]
fn generic_inference_server_drives_sharded_backend() {
    // The coordinator::server batching path with a ShardedExecutor
    // backend instead of the PJRT driver — no artifacts needed.
    let net = trained_net(11);
    let cfg = net.cfg.clone();
    let p = plan(&cfg, 2, KernelVersion::Infer, &FpgaDevice::u55c()).unwrap();
    let server = InferenceServer::start(
        move || ShardedExecutor::new(net, &p),
        ServerConfig {
            queue_depth: 64,
            flush_timeout: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let d = synth::generate(cfg.img_side, cfg.n_classes, 40, 5, 0.15);
    let handles: Vec<_> = d
        .images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &handles {
        let probs = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(probs.len(), cfg.n_out());
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 40);
    assert!(rep.mean_fill > 1.0, "no batching: {}", rep.mean_fill);
}

#[test]
fn cluster_round_robin_spreads_load() {
    let cfg = by_name("tiny").unwrap();
    let server = ClusterServer::start(
        &cfg,
        42,
        ClusterConfig {
            replicas: 2,
            shards_per_replica: 2,
            queue_depth: 128,
            flush_timeout: Duration::from_millis(2),
            policy: SchedulePolicy::RoundRobin,
            ..ClusterConfig::default()
        },
    )
    .unwrap();

    let d = synth::generate(cfg.img_side, cfg.n_classes, 64, 3, 0.15);
    let handles: Vec<_> = d
        .images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &handles {
        let probs = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(probs.len(), cfg.n_out());
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 64);
    assert_eq!(rep.rerouted, 0);
    assert_eq!(rep.replicas.len(), 2);
    // Round-robin alternates, so each replica served exactly half.
    assert_eq!(rep.replicas[0].served, 32);
    assert_eq!(rep.replicas[1].served, 32);
    assert_eq!(rep.latency.count, 64);
    // Per-shard reports: every device saw every image of its replica.
    for r in &rep.replicas {
        assert_eq!(r.shards.len(), 2);
        for s in &r.shards {
            assert_eq!(s.items, r.served);
        }
    }
}

#[test]
fn cluster_failover_reroutes_without_loss() {
    let cfg = by_name("tiny").unwrap();
    let server = ClusterServer::start(
        &cfg,
        42,
        ClusterConfig {
            replicas: 2,
            shards_per_replica: 2,
            queue_depth: 128,
            // Long flush: the failing replica collects the whole burst
            // into one batch before noticing the injected failure.
            flush_timeout: Duration::from_millis(500),
            policy: SchedulePolicy::LeastOutstanding,
            ..ClusterConfig::default()
        },
    )
    .unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 16, 5, 0.15);

    // Warm-up: replica 0 serves normally.
    let warm: Vec<_> = d.images[..3]
        .iter()
        .map(|img| server.submit_to(0, img.clone()).unwrap())
        .collect();
    for rx in &warm {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }

    // Kill replica 0, then aim a burst straight at it: every request
    // must come back anyway, served by replica 1.
    server.fail_replica(0);
    assert_eq!(server.healthy_replicas(), 1);
    let burst: Vec<_> = d.images[3..8]
        .iter()
        .map(|img| server.submit_to(0, img.clone()).unwrap())
        .collect();
    for rx in &burst {
        let probs = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(probs.len(), cfg.n_out());
    }

    // Scheduled traffic now avoids the dead replica.
    let tail: Vec<_> = d.images[8..]
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &tail {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }

    let rep = server.shutdown();
    assert_eq!(rep.served, 16, "no request may be lost");
    // The worker re-routes every burst request it received before
    // retiring (>= 1 by construction; all 5 in the common schedule).
    // Any stragglers racing the queue close are re-routed client-side
    // by submit_to, which keeps `served` whole without counting here.
    assert!(rep.rerouted >= 1, "burst was not re-routed: {}", rep.rerouted);
    assert!(rep.replicas[0].failed);
    assert_eq!(rep.replicas[0].served, 3);
    assert!(rep.replicas[0].rerouted_out >= 1);
    assert!(!rep.replicas[1].failed);
    assert_eq!(rep.replicas[1].served, 13);
}

#[test]
fn all_replicas_down_rejects_new_traffic() {
    let cfg = by_name("tiny").unwrap();
    let server = ClusterServer::start(&cfg, 1, ClusterConfig {
        replicas: 1,
        shards_per_replica: 1,
        ..ClusterConfig::default()
    })
    .unwrap();
    server.fail_replica(0);
    let err = server
        .submit(vec![0.5; cfg.hc_in()])
        .err()
        .map(|e| e.to_string())
        .unwrap_or_default();
    assert!(err.contains("no healthy replicas"), "{err}");
    let rep = server.shutdown();
    assert_eq!(rep.served, 0);
}
