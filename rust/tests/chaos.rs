//! Chaos-plane system tests: the serving invariants under scripted and
//! seeded-random fault schedules (DESIGN.md §10).
//!
//! The contract these pin, end to end against real clusters:
//!
//! - **nothing lost, nothing double-answered** — every submitted
//!   request ends in exactly one typed outcome while at least one
//!   replica survives, across random crash/devloss/slow/stall/revive
//!   schedules;
//! - **resurrection restores service** — a crashed replica respawned
//!   mid-traffic rejoins the scheduler pool, serves, and reports a
//!   clean (failed = false) final incarnation;
//! - **reproducibility** — the same plan against the same traffic
//!   yields a byte-identical outcome digest;
//! - **typed sheds** — deadlines and admission control answer with
//!   `DeadlineExceeded` / `Overloaded`, never a dropped channel;
//! - **the degradation ladder** walks a breaching server down to int8
//!   weights and typed shedding.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bcpnn_accel::chaos::{run_chaos, DegradeConfig, DegradeLevel, FaultPlan};
use bcpnn_accel::cluster::{ClusterConfig, ClusterServer, SchedulePolicy};
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::{
    Admission, InferBackend, InferenceServer, ServeError, ServerConfig,
};
use bcpnn_accel::data::synth;
use bcpnn_accel::testing::prop_check;

fn tiny_cluster(replicas: usize, ccfg_over: ClusterConfig) -> (ClusterServer, Vec<Vec<f32>>) {
    let cfg = by_name("tiny").unwrap();
    let server = ClusterServer::start(
        &cfg,
        42,
        ClusterConfig { replicas, shards_per_replica: 2, ..ccfg_over },
    )
    .unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 120, 7, 0.15);
    (server, d.images)
}

#[test]
fn seeded_random_plans_lose_nothing() {
    // Random fault schedules, constrained so >= 1 replica survives at
    // every point: every request must come back served (no deadlines,
    // blocking admission), none lost, none double-answered.
    prop_check(
        "chaos_no_loss",
        0xC4A05,
        4,
        |rng| FaultPlan::random(rng, 3, 120),
        |plan| {
            let (server, images) = tiny_cluster(3, ClusterConfig::default());
            let outcome = run_chaos(server, plan.clone(), &images, None);
            if outcome.lost != 0 {
                return Err(format!(
                    "{} requests lost: {}",
                    outcome.lost,
                    outcome.determinism_key()
                ));
            }
            if outcome.double_answered != 0 {
                return Err(format!("{} double answers", outcome.double_answered));
            }
            if outcome.served != outcome.requests {
                return Err(format!(
                    "served {} of {}: {}",
                    outcome.served,
                    outcome.requests,
                    outcome.determinism_key()
                ));
            }
            if outcome.report.served != outcome.served {
                return Err(format!(
                    "report counts {} served, clients saw {}",
                    outcome.report.served, outcome.served
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn crash_and_resurrect_rejoins_and_serves() {
    let plan = FaultPlan::parse("crash:replica0@40,revive:replica0@80").unwrap();
    let run = || {
        let (server, images) = tiny_cluster(
            2,
            ClusterConfig { policy: SchedulePolicy::LeastOutstanding, ..ClusterConfig::default() },
        );
        let mut images = images;
        images.extend(images.clone()); // 240 requests: traffic after the revive
        run_chaos(server, plan.clone(), &images, None)
    };
    let outcome = run();

    assert_eq!(outcome.lost, 0, "{}", outcome.determinism_key());
    assert_eq!(outcome.double_answered, 0);
    assert_eq!(outcome.served, outcome.requests, "{}", outcome.determinism_key());
    assert_eq!(outcome.resurrections, 1);

    // Three incarnation reports: replica 0's failed life, its healthy
    // respawn, and replica 1 — ordered by (replica, incarnation).
    assert_eq!(outcome.report.replicas.len(), 3);
    let r0_first = &outcome.report.replicas[0];
    let r0_second = &outcome.report.replicas[1];
    let r1 = &outcome.report.replicas[2];
    assert_eq!((r0_first.replica, r0_first.incarnation), (0, 0));
    assert_eq!((r0_second.replica, r0_second.incarnation), (0, 1));
    assert_eq!((r1.replica, r1.incarnation), (1, 0));
    assert!(r0_first.failed, "first incarnation was crashed");
    assert!(!r0_first.panicked);
    assert!(!r0_second.failed, "resurrected incarnation must report healthy");
    assert!(
        r0_second.served > 0,
        "resurrected replica rejoined the pool but served nothing"
    );
    assert!(!r1.failed);
    assert_eq!(outcome.report.panics, 0);

    // Byte-reproducible: same plan, same traffic, same digest.
    let again = run();
    assert_eq!(outcome.determinism_key(), again.determinism_key());
}

#[test]
fn zero_deadline_sheds_everything_typed() {
    let (server, images) = tiny_cluster(2, ClusterConfig::default());
    let outcome = run_chaos(
        server,
        FaultPlan::default(),
        &images[..24],
        Some(Duration::ZERO),
    );
    assert_eq!(outcome.served, 0, "{}", outcome.determinism_key());
    assert_eq!(outcome.shed_deadline, 24, "every request must get a typed deadline error");
    assert_eq!(outcome.lost, 0);
    assert_eq!(outcome.double_answered, 0);
}

/// Slow backend for overload tests: 1-image batches, fixed sleep.
struct SlowBackend {
    sleep: Duration,
    calls: Arc<AtomicU64>,
}

impl InferBackend for SlowBackend {
    fn max_batch(&self) -> usize {
        1
    }

    fn infer_batch(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(self.sleep);
        Ok(images.iter().map(|img| vec![img[0]]).collect())
    }
}

#[test]
fn shed_admission_rejects_overload_at_the_front_door() {
    // Queue of 2 + 20 ms service + shed admission: a burst of 30
    // instant submissions must split into served + typed Overloaded,
    // with nothing lost and nothing blocked.
    let server = InferenceServer::start(
        || {
            Ok(SlowBackend {
                sleep: Duration::from_millis(20),
                calls: Arc::new(AtomicU64::new(0)),
            })
        },
        ServerConfig {
            queue_depth: 2,
            flush_timeout: Duration::from_millis(1),
            admission: Admission::Shed,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let mut tickets = Vec::new();
    let mut overloaded = 0u64;
    for i in 0..30 {
        match server.submit(vec![i as f32]) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Overloaded { queue_depth }) => {
                assert_eq!(queue_depth, 2);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(overloaded > 0, "a 2-deep queue cannot absorb a 30-burst at 20 ms/req");

    let mut served = 0u64;
    for t in &tickets {
        t.wait().unwrap();
        served += 1;
        assert!(t.extra_response().is_none());
    }
    assert_eq!(served + overloaded, 30, "shed + served must partition the burst");

    // Front-door sheds are visible on the metrics counter (they never
    // reach the worker, so the report's worker-side column stays 0).
    assert_eq!(server.metrics().counter("serve.shed_overload").get(), overloaded);
    let rep = server.shutdown();
    assert_eq!(rep.served, served);
    assert!(!rep.panicked);
}

#[test]
fn degradation_ladder_walks_to_int8_and_shedding() {
    use bcpnn_accel::bcpnn::{LayerGraph, QuantFormat};
    use bcpnn_accel::coordinator::GraphBackend;

    // An unmeetable p99 target (1 ns): every batch breaches, so with
    // breach_rounds = 2 the ladder escalates on batches 2 (int8), 4
    // (short flush), 6 (shedding); requests after that are shed with
    // typed Overloaded once their queue wait exceeds the target.
    let cfg = by_name("tiny").unwrap();
    let graph = LayerGraph::new(cfg.clone(), 3);
    let server = InferenceServer::start(
        move || Ok(GraphBackend::new(graph, 1)),
        ServerConfig {
            queue_depth: 64,
            flush_timeout: Duration::from_micros(200),
            degrade: Some(DegradeConfig {
                p99_target_ms: 1e-6,
                breach_rounds: 2,
                recover_rounds: 1_000_000,
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let d = synth::generate(cfg.img_side, cfg.n_classes, 24, 5, 0.15);
    let mut served = 0u64;
    let mut shed = 0u64;
    // One request at a time -> one batch each -> a deterministic walk
    // up the ladder.
    for img in &d.images {
        let t = server.submit(img.clone()).unwrap();
        match t.wait() {
            Ok(probs) => {
                assert_eq!(probs.len(), cfg.n_out());
                served += 1;
            }
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    let rep = server.shutdown();
    assert_eq!(served + shed, 24);
    assert!(served >= 2, "pre-escalation batches serve normally");
    assert!(shed >= 1, "the shedding rung must shed typed Overloaded");
    assert_eq!(rep.shed_overload, shed);
    assert_eq!(
        rep.degrade_level,
        DegradeLevel::Shedding.index(),
        "ladder should sit on the top rung"
    );
    assert_eq!(
        rep.precision,
        QuantFormat::Int8,
        "Quantized rung requantizes the live GraphBackend store"
    );
}

#[test]
fn device_loss_reroutes_like_a_crash() {
    // A devloss fault fires through HybridExecutor::fail_device — the
    // replica discovers the loss itself and walks the ordinary failure
    // path; clients never see the difference.
    let plan = FaultPlan::parse("devloss:replica1.0@30").unwrap();
    let (server, images) = tiny_cluster(2, ClusterConfig::default());
    let outcome = run_chaos(server, plan, &images, None);
    assert_eq!(outcome.lost, 0, "{}", outcome.determinism_key());
    assert_eq!(outcome.served, outcome.requests);
    assert_eq!(outcome.double_answered, 0);
    let r1_failed = outcome
        .report
        .replicas
        .iter()
        .any(|r| r.replica == 1 && r.failed);
    assert!(r1_failed, "device loss must retire its replica");
}
