//! Bitwise-equivalence suite: block-sparse active-synapse kernels vs
//! the preserved dense seed loops (`bcpnn::sparse::dense_*`), across
//! the whole config registry.
//!
//! The dense loops are the numeric oracle (they are the seed
//! implementation verbatim); the production kernels walk only active
//! spans. Everything an external observer can see must be bitwise
//! identical: inference outputs, support vectors and their shard
//! slices, every probability trace, and every weight the mask exposes.
//! Weights of *inactive* synapses are deliberately not maintained by
//! the sparse path (they are re-derived on activation), so wij is
//! compared under the mask.
//!
//! The batched AoSoA **tile** engine (`sparse::*_tile`, TILE = 8
//! lane-interleaved images per span walk) is pinned here too: every
//! registry config's tile inference, tile shard slices, ragged tails
//! (batch % TILE != 0), and the `--threads` batch splitter must be
//! bitwise the single-image span kernels — and hence the dense seed.

use bcpnn_accel::bcpnn::checkpoint::{load_graph, save_graph};
use bcpnn_accel::bcpnn::sparse::{
    dense_support_cols, dense_support_masked, dense_train_step, expand_mask_dims, TILE,
};
use bcpnn_accel::bcpnn::{
    LayerGraph, Network, Projection, QuantFormat, StructuralPlasticity, Workspace,
};
use bcpnn_accel::config::{by_name, registry, ModelConfig};
use bcpnn_accel::data::encode::{encode_image, pack_tile, unpack_lane};
use bcpnn_accel::data::synth;
use bcpnn_accel::testing::prop_check;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Dense mirror of one projection: the seed representation (full
/// arrays + expanded f32 unit mask), trained with the seed loops.
struct DenseProj {
    hc_out: usize,
    mc_out: usize,
    pi: Vec<f32>,
    pj: Vec<f32>,
    pij: Vec<f32>,
    wij: Vec<f32>,
    bj: Vec<f32>,
    mask_hc: Vec<f32>,
    mask_unit: Vec<f32>,
    hc_in: usize,
    mc_in: usize,
}

impl DenseProj {
    fn of(p: &Projection) -> DenseProj {
        DenseProj {
            hc_out: p.dims.hc_out,
            mc_out: p.dims.mc_out,
            pi: p.pi.clone(),
            pj: p.pj.clone(),
            pij: p.pij.clone(),
            wij: p.wij.clone(),
            bj: p.bj.clone(),
            mask_hc: p.mask_hc.clone(),
            mask_unit: p.dense_mask(),
            hc_in: p.dims.hc_in,
            mc_in: p.dims.mc_in,
        }
    }

    fn support(&self, x: &[f32]) -> Vec<f32> {
        dense_support_masked(&self.bj, &self.wij, &self.mask_unit, x)
    }

    fn activate(&self, x: &[f32], gain: f32) -> Vec<f32> {
        let mut s = self.support(x);
        Network::hc_softmax(&mut s, self.hc_out, self.mc_out, gain);
        s
    }

    fn train(&mut self, x: &[f32], y: &[f32], alpha: f32, eps: f32) {
        dense_train_step(
            &mut self.pi, &mut self.pj, &mut self.pij, &mut self.wij, &mut self.bj,
            x, y, alpha, eps,
        );
    }

    fn set_mask(&mut self, mask_hc: &[f32]) {
        self.mask_hc = mask_hc.to_vec();
        self.mask_unit =
            expand_mask_dims(&self.mask_hc, self.hc_in, self.hc_out, self.mc_in, self.mc_out);
    }
}

/// Compare a sparse projection against its dense mirror: traces and
/// bias everywhere, weights under the mask.
fn assert_state_matches(p: &Projection, d: &DenseProj, what: &str) {
    assert_eq!(bits(&p.pi), bits(&d.pi), "{what}: pi");
    assert_eq!(bits(&p.pj), bits(&d.pj), "{what}: pj");
    assert_eq!(bits(&p.pij), bits(&d.pij), "{what}: pij");
    assert_eq!(bits(&p.bj), bits(&d.bj), "{what}: bj");
    assert_eq!(p.mask_hc, d.mask_hc, "{what}: mask");
    for (idx, (&w, &m)) in p.wij.iter().zip(&d.mask_unit).enumerate() {
        if m != 0.0 {
            assert_eq!(w.to_bits(), d.wij[idx].to_bits(), "{what}: wij[{idx}]");
        }
    }
}

/// Dense forward pass of a whole graph (seed semantics; the head is
/// unmasked, so its kernels are shared with the sparse path).
fn dense_forward(g: &LayerGraph, mirrors: &[DenseProj], img: &[f32]) -> (Vec<f32>, Vec<Vec<f32>>) {
    let x = encode_image(img);
    let mut acts: Vec<Vec<f32>> = Vec::new();
    for m in mirrors {
        let input: &[f32] = if acts.is_empty() { &x } else { acts.last().unwrap() };
        acts.push(m.activate(input, g.cfg.gain));
    }
    (x, acts)
}

/// Pin the batched AoSoA tile engine against the dense mirrors: whole-
/// batch tile inference (ragged tails included — the registry batches
/// are 2..8 images, so both full and partial tiles occur), the
/// threaded batch splitter, and the tile shard slices the hybrid
/// executor runs on.
fn assert_tiles_equivalent(
    name: &str, g: &LayerGraph, mirrors: &[DenseProj], images: &[Vec<f32>], what: &str,
) {
    // Whole-batch tile inference vs dense per-image probabilities.
    let batch = g.infer_batch(images);
    for (k, (img, got)) in images.iter().zip(&batch).enumerate() {
        let (_, acts) = dense_forward(g, mirrors, img);
        let want = g.head.activate_dense(acts.last().unwrap());
        assert_eq!(bits(got), bits(&want), "{name}: tile infer {what} img {k}");
    }
    // The data-parallel splitter returns identical bits at any count.
    for threads in [2usize, 3] {
        let thr = g.infer_batch_threads(images, threads);
        assert_eq!(batch, thr, "{name}: {threads}-thread splitter {what}");
    }
    // Tile shard slices vs the dense cols oracle, lane by lane.
    for chunk in images.chunks(TILE) {
        let mut inputs: Vec<Vec<f32>> = chunk.iter().map(|i| encode_image(i)).collect();
        for (l, (p, m)) in g.layers.iter().zip(mirrors).enumerate() {
            let mut xt = Vec::new();
            pack_tile(&inputs, &mut xt);
            let n_out = p.dims.n_out();
            for cut in (1..p.dims.hc_out).take(2) {
                let mid = cut * p.dims.mc_out;
                let mut lo_t = Vec::new();
                p.support_cols_tile_into(&xt, 0, mid, &mut lo_t);
                let mut hi_t = Vec::new();
                p.support_cols_tile_into(&xt, mid, n_out, &mut hi_t);
                for (lane, x) in inputs.iter().enumerate() {
                    let lo_d = dense_support_cols(&m.bj, &m.wij, &m.mask_unit, x, 0, mid);
                    let hi_d =
                        dense_support_cols(&m.bj, &m.wij, &m.mask_unit, x, mid, n_out);
                    assert_eq!(
                        bits(&unpack_lane(&lo_t, lane)), bits(&lo_d),
                        "{name} {what} l{l} cut {cut} lane {lane} lo"
                    );
                    assert_eq!(
                        bits(&unpack_lane(&hi_t, lane)), bits(&hi_d),
                        "{name} {what} l{l} cut {cut} lane {lane} hi"
                    );
                }
            }
            inputs = inputs.iter().map(|x| m.activate(x, g.cfg.gain)).collect();
        }
    }
}

fn imgs_for(cfg: &ModelConfig, seed: u64) -> Vec<Vec<f32>> {
    // Large paper models get a reduced batch so the debug-build suite
    // stays fast; the math is per-image, so coverage is unaffected.
    let n = if cfg.n_in() * cfg.n_h() > 1_000_000 { 2 } else { cfg.batch.clamp(4, 8) };
    synth::generate(cfg.img_side, cfg.n_classes, n, seed, 0.15).images
}

/// The full per-config oracle: fresh graph vs dense mirrors through
/// inference, shard slices, one train batch, and rewire-then-refresh.
fn assert_config_equivalent(name: &str) {
    let cfg = by_name(name).unwrap();
    let mut g = LayerGraph::new(cfg.clone(), 42);
    let mut mirrors: Vec<DenseProj> = g.layers.iter().map(DenseProj::of).collect();
    let images = imgs_for(&cfg, 42);

    // --- inference + shard slices before training
    for (k, img) in images.iter().enumerate() {
        let (x, acts) = dense_forward(&g, &mirrors, img);
        let dense_probs = g.head.activate_dense(acts.last().unwrap());
        assert_eq!(bits(&g.infer(img)), bits(&dense_probs), "{name}: infer pre-train img {k}");

        // Shard slices: every hypercolumn-aligned cut of every layer.
        for (l, (p, m)) in g.layers.iter().zip(&mirrors).enumerate() {
            let input: &[f32] = if l == 0 { &x } else { &acts[l - 1] };
            let n_out = p.dims.n_out();
            let cuts: Vec<usize> = (1..p.dims.hc_out).take(4).collect();
            for cut in cuts {
                let mid = cut * p.dims.mc_out;
                let lo_s = p.support_cols(input, 0, mid);
                let hi_s = p.support_cols(input, mid, n_out);
                let lo_d = dense_support_cols(&m.bj, &m.wij, &m.mask_unit, input, 0, mid);
                let hi_d = dense_support_cols(&m.bj, &m.wij, &m.mask_unit, input, mid, n_out);
                assert_eq!(bits(&lo_s), bits(&lo_d), "{name} l{l} cut {cut} lo");
                assert_eq!(bits(&hi_s), bits(&hi_d), "{name} l{l} cut {cut} hi");
            }
        }
    }

    // --- batched tile engine, fresh weights.
    assert_tiles_equivalent(name, &g, &mirrors, &images, "pre-train");

    // --- one train batch (unsupervised greedy layer-wise + head sup),
    // sparse graph vs dense mirrors running the seed loops.
    let (alpha, eps, gain) = (cfg.alpha, cfg.eps, cfg.gain);
    for img in &images {
        g.train_unsup_step(img);
        let x = encode_image(img);
        let mut input = x;
        for m in mirrors.iter_mut() {
            let y = m.activate(&input, gain);
            m.train(&input, &y, alpha, eps);
            input = y;
        }
    }
    // Head supervised pass runs inside g only: the head is unmasked
    // (full block index), so its train_step covers every entry — the
    // dense-vs-sparse question doesn't arise for it.
    for (k, img) in images.iter().enumerate() {
        g.train_sup_step(img, k % cfg.n_classes);
    }
    for (l, (p, m)) in g.layers.iter().zip(&mirrors).enumerate() {
        assert_state_matches(p, m, &format!("{name}: layer {l} post-train"));
    }
    for (k, img) in images.iter().enumerate() {
        let (_, acts) = dense_forward(&g, &mirrors, img);
        let dense_probs = g.head.activate_dense(acts.last().unwrap());
        assert_eq!(bits(&g.infer(img)), bits(&dense_probs), "{name}: infer post-train img {k}");
    }

    // --- rewire, then refresh: newly activated blocks must carry the
    // weights the dense path maintained all along.
    let stats = g.rewire(&StructuralPlasticity::default());
    for (l, (p, m)) in g.layers.iter().zip(mirrors.iter_mut()).enumerate() {
        // The mirror adopts the rewired mask; its dense wij was always
        // fresh, so no other state changes.
        m.set_mask(&p.mask_hc);
        assert_state_matches(p, m, &format!("{name}: layer {l} post-rewire ({stats:?})"));
    }
    for (k, img) in images.iter().enumerate() {
        let (_, acts) = dense_forward(&g, &mirrors, img);
        let dense_probs = g.head.activate_dense(acts.last().unwrap());
        assert_eq!(bits(&g.infer(img)), bits(&dense_probs), "{name}: infer post-rewire img {k}");
    }

    // --- batched tile engine on the trained-and-rewired weights (the
    // tile kernels run the rebuilt block index too).
    assert_tiles_equivalent(name, &g, &mirrors, &images, "post-rewire");

    // --- one more training step after the rewire (the sparse weight
    // map now runs on the new index).
    let img = &images[0];
    g.train_unsup_step(img);
    {
        let x = encode_image(img);
        let mut input = x;
        for m in mirrors.iter_mut() {
            let y = m.activate(&input, gain);
            m.train(&input, &y, alpha, eps);
            input = y;
        }
    }
    for (l, (p, m)) in g.layers.iter().zip(&mirrors).enumerate() {
        assert_state_matches(p, m, &format!("{name}: layer {l} post-rewire-train"));
    }
}

#[test]
fn registry_small_configs_bitwise_equivalent() {
    for name in ["tiny", "small", "edge", "toy-deep"] {
        assert_config_equivalent(name);
    }
}

#[test]
fn registry_model1_bitwise_equivalent() {
    assert_config_equivalent("model1");
}

#[test]
fn registry_model2_bitwise_equivalent() {
    assert_config_equivalent("model2");
}

#[test]
fn registry_model3_bitwise_equivalent() {
    assert_config_equivalent("model3");
}

#[test]
fn registry_mnist_deep2_bitwise_equivalent() {
    assert_config_equivalent("mnist-deep2");
}

#[test]
fn suite_tracks_registry() {
    // Every registry config must be named in a test above.
    let covered = [
        "tiny", "small", "edge", "toy-deep", "model1", "model2", "model3",
        "mnist-deep2",
    ];
    let mut names: Vec<String> = registry().keys().cloned().collect();
    names.sort();
    let mut want: Vec<String> = covered.iter().map(|s| s.to_string()).collect();
    want.sort();
    assert_eq!(names, want, "registry changed: extend rust/tests/kernels.rs");
}

#[test]
fn network_kernels_match_dense_reference() {
    // The classic two-projection Network runs the same block-sparse
    // engine; pin its support + train loops against the dense oracle.
    let cfg = by_name("tiny").unwrap();
    let mut net = Network::new(cfg.clone(), 5);
    let images = imgs_for(&cfg, 5);
    let dims = cfg.layer_dims()[0];
    let mut mask_unit =
        expand_mask_dims(&net.params.mask_hc, dims.hc_in, dims.hc_out, dims.mc_in, dims.mc_out);
    for img in &images {
        let x = encode_image(img);
        let want = dense_support_masked(&net.params.bj, &net.params.wij, &mask_unit, &x);
        assert_eq!(bits(&net.support(&x)), bits(&want));
        net.train_unsup_step(img);
        // Dense oracle for the *next* support needs the mirror to
        // train too; instead of duplicating state, re-expand the mask
        // (unchanged) and compare against the network's own arrays —
        // valid because assert_state coverage lives in the graph suite
        // and Network/LayerGraph equality is pinned by deep_stack.
        mask_unit = expand_mask_dims(
            &net.params.mask_hc, dims.hc_in, dims.hc_out, dims.mc_in, dims.mc_out,
        );
    }
    // After rewiring, support must still match the dense loop over the
    // network's (re-derived) weights.
    let sp = StructuralPlasticity::default();
    sp.rewire(&mut net.params, &cfg);
    net.refresh_mask();
    mask_unit = expand_mask_dims(
        &net.params.mask_hc, dims.hc_in, dims.hc_out, dims.mc_in, dims.mc_out,
    );
    for img in &images {
        let x = encode_image(img);
        let want = dense_support_masked(&net.params.bj, &net.params.wij, &mask_unit, &x);
        assert_eq!(bits(&net.support(&x)), bits(&want));
        for cut in 1..dims.hc_out {
            let mid = cut * dims.mc_out;
            let want_lo =
                dense_support_cols(&net.params.bj, &net.params.wij, &mask_unit, &x, 0, mid);
            assert_eq!(bits(&net.support_cols(&x, 0, mid)), bits(&want_lo), "cut {cut}");
        }
    }
}

#[test]
fn multi_tile_ragged_batches_bitwise_match_per_image() {
    // Batches spanning several tiles with every tail shape: the tile
    // grouping (and the threaded splitter's regrouping) must never
    // show through in the bits.
    let cfg = by_name("tiny").unwrap();
    let mut g = LayerGraph::new(cfg.clone(), 31);
    let d = synth::generate(cfg.img_side, cfg.n_classes, 2 * TILE + 5, 8, 0.15);
    // Train a little so weights are non-trivial.
    for img in &d.images[..6] {
        g.train_unsup_step(img);
    }
    for n in [1usize, TILE - 1, TILE, TILE + 1, 2 * TILE + 5] {
        let imgs = &d.images[..n];
        let want: Vec<Vec<u32>> = imgs.iter().map(|i| bits(&g.infer(i))).collect();
        let batch = g.infer_batch(imgs);
        for (k, (got, w)) in batch.iter().zip(&want).enumerate() {
            assert_eq!(&bits(got), w, "n={n} img {k}");
        }
        for threads in [2usize, 4, 9] {
            let thr = g.infer_batch_threads(imgs, threads);
            assert_eq!(batch, thr, "n={n} threads={threads}");
        }
    }
}

#[test]
fn workspace_reuse_across_configs_is_exact() {
    // One process, one Workspace, three configs with different buffer
    // shapes (including shrinking back to a smaller model): buffer
    // resizing must never leak state between models. Exercises both
    // the scalar and the tile paths.
    let names = ["tiny", "toy-deep", "small", "tiny"];
    let mut shared = Workspace::new();
    for (round, name) in names.iter().enumerate() {
        let cfg = by_name(name).unwrap();
        let g = LayerGraph::new(cfg.clone(), 17);
        let d = synth::generate(cfg.img_side, cfg.n_classes, TILE + 2, round as u64, 0.15);
        for (k, img) in d.images.iter().enumerate() {
            let want = g.infer(img); // fresh workspace inside
            let got = g.infer_with(img, &mut shared);
            assert_eq!(bits(got), bits(&want), "{name} round {round} img {k} scalar");
        }
        for chunk in d.images.chunks(TILE) {
            let tile = g.infer_tile_with(chunk, &mut shared);
            for (lane, img) in chunk.iter().enumerate() {
                let want = g.infer(img);
                assert_eq!(
                    bits(&unpack_lane(tile, lane)),
                    bits(&want),
                    "{name} round {round} lane {lane} tile"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Quantized weight-store suite. The narrow store is a derived view of
// the f32 masters: selecting `F32` must leave every kernel above
// bitwise untouched (it drops the store), and each narrow format must
// track the f32 probabilities within a named epsilon on every registry
// config — on fresh weights, and again after training + rewire (the
// requantize hooks rebuild the store over the refreshed spans).

/// Max |p_quant - p_f32| allowed over output probabilities, per
/// format. All registry configs run gain = 1.0, so a support error d
/// moves a probability by at most ~d/2; these bounds carry an order of
/// magnitude of headroom over the worst weight-rounding drift observed
/// in the registry regime (fresh-to-lightly-trained weights, |w| well
/// under 1), while a broken dequant path shows diffs near 1.0.
const BF16_PROB_EPS: f32 = 0.03;
const F16_PROB_EPS: f32 = 0.03;
const INT8_PROB_EPS: f32 = 0.10;

fn prob_eps(fmt: QuantFormat) -> f32 {
    match fmt {
        QuantFormat::F32 => 0.0,
        QuantFormat::Bf16 => BF16_PROB_EPS,
        QuantFormat::F16 => F16_PROB_EPS,
        QuantFormat::Int8 => INT8_PROB_EPS,
    }
}

/// Per-config oracle: f32-format selection is bitwise inert; every
/// narrow format stays within its probability epsilon of f32 on the
/// scalar path, and its tile/threaded batch paths are bitwise the
/// scalar quantized path (dequant is per-weight, so lane grouping must
/// not show through — same contract the f32 engine pins above).
fn assert_quantized_tracks_f32(name: &str) {
    let cfg = by_name(name).unwrap();
    let mut g = LayerGraph::new(cfg.clone(), 42);
    let images = imgs_for(&cfg, 97);

    let check = |g: &LayerGraph, what: &str| {
        let want: Vec<Vec<f32>> = images.iter().map(|i| g.infer(i)).collect();

        // Explicitly selecting F32 drops the store: bitwise identical
        // to a graph that never touched precision.
        let mut gf = g.clone();
        gf.set_precision(QuantFormat::F32);
        assert_eq!(gf.precision(), QuantFormat::F32);
        for (k, (img, w)) in images.iter().zip(&want).enumerate() {
            assert_eq!(bits(&gf.infer(img)), bits(w), "{name} {what}: f32 format img {k}");
        }

        for fmt in [QuantFormat::Bf16, QuantFormat::F16, QuantFormat::Int8] {
            let mut gq = g.clone();
            gq.set_precision(fmt);
            assert_eq!(gq.precision(), fmt);
            let eps = prob_eps(fmt);
            let scalar: Vec<Vec<f32>> = images.iter().map(|i| gq.infer(i)).collect();
            for (k, (got, w)) in scalar.iter().zip(&want).enumerate() {
                let d = got
                    .iter()
                    .zip(w.iter())
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    d <= eps,
                    "{name} {what}: {} img {k} drifted {d:e} > {eps:e} from f32",
                    fmt.name()
                );
            }
            let batch = gq.infer_batch(&images);
            for (k, (got, s)) in batch.iter().zip(&scalar).enumerate() {
                assert_eq!(bits(got), bits(s), "{name} {what}: {} tile img {k}", fmt.name());
            }
            for threads in [2usize, 3] {
                let thr = gq.infer_batch_threads(&images, threads);
                assert_eq!(batch, thr, "{name} {what}: {} x{threads} threads", fmt.name());
            }
        }
    };

    check(&g, "pre-train");

    // Short train batch + rewire, then re-check: the narrow stores are
    // rebuilt from the refreshed spans (set_precision on the trained
    // graph exercises the same build the requantize hooks run).
    for (k, img) in images.iter().enumerate() {
        g.train_unsup_step(img);
        g.train_sup_step(img, k % cfg.n_classes);
    }
    g.rewire(&StructuralPlasticity::default());
    check(&g, "post-rewire");
}

#[test]
fn quantized_small_configs_track_f32() {
    for name in ["tiny", "small", "edge", "toy-deep"] {
        assert_quantized_tracks_f32(name);
    }
}

#[test]
fn quantized_model1_tracks_f32() {
    assert_quantized_tracks_f32("model1");
}

#[test]
fn quantized_model2_tracks_f32() {
    assert_quantized_tracks_f32("model2");
}

#[test]
fn quantized_model3_tracks_f32() {
    assert_quantized_tracks_f32("model3");
}

#[test]
fn quantized_mnist_deep2_tracks_f32() {
    assert_quantized_tracks_f32("mnist-deep2");
}

#[test]
fn quantized_checkpoint_roundtrip_preserves_format() {
    // A quantized graph checkpoints its f32 masters plus the precision
    // tag; loading rebuilds the narrow store and must reproduce the
    // quantized inference bitwise.
    let cfg = by_name("toy-deep").unwrap();
    let mut g = LayerGraph::new(cfg.clone(), 7);
    let images = imgs_for(&cfg, 7);
    for (k, img) in images.iter().enumerate() {
        g.train_unsup_step(img);
        g.train_sup_step(img, k % cfg.n_classes);
    }
    for fmt in [QuantFormat::Bf16, QuantFormat::F16, QuantFormat::Int8] {
        g.set_precision(fmt);
        let mut path = std::env::temp_dir();
        path.push(format!("bcpnn_kernels_q_{}_{}", fmt.name(), std::process::id()));
        save_graph(&path, &g).unwrap();
        let loaded = load_graph(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.precision(), fmt, "format tag survives the roundtrip");
        for (k, img) in images.iter().enumerate() {
            assert_eq!(
                bits(&loaded.infer(img)),
                bits(&g.infer(img)),
                "{} img {k}: loaded store diverged",
                fmt.name()
            );
        }
    }
}

#[test]
fn random_hc_mask_edits_keep_equivalence() {
    // Property: any hypercolumn-aligned mask edit (random flips of
    // whole HC blocks), followed by refresh, keeps the block-sparse
    // kernels bitwise equal to the dense loops — including the weight
    // re-derivation for blocks the edit switches on.
    let cfg = by_name("tiny").unwrap();
    prop_check(
        "hc-mask-edits-keep-equivalence",
        0xB10C,
        12,
        |rng| {
            let seed = rng.next_u64();
            let flips: Vec<usize> = (0..6).map(|_| rng.next_range(64 * 4)).collect();
            let img: Vec<f32> = (0..64).map(|_| rng.next_f32()).collect();
            (seed, flips, img)
        },
        |(seed, flips, img)| {
            let cfg = cfg.clone();
            let mut g = LayerGraph::new(cfg.clone(), *seed);
            // A little training so traces/weights are non-trivial.
            let d = synth::generate(cfg.img_side, cfg.n_classes, 6, *seed, 0.15);
            let mut mirror = DenseProj::of(&g.layers[0]);
            for timg in &d.images {
                g.train_unsup_step(timg);
                let x = encode_image(timg);
                let y = mirror.activate(&x, cfg.gain);
                mirror.train(&x, &y, cfg.alpha, cfg.eps);
            }
            // Apply the same HC-block flips to both sides.
            let mut mask = g.layers[0].mask_hc.clone();
            for &f in flips {
                mask[f] = 1.0 - mask[f];
            }
            g.layers[0].mask_hc.copy_from_slice(&mask);
            g.refresh_masks();
            mirror.set_mask(&mask);

            let x = encode_image(img);
            let got = g.layers[0].support_masked(&x);
            let want = mirror.support(&x);
            if bits(&got) != bits(&want) {
                return Err("support diverged after mask edit".into());
            }
            // One more train step on the edited wiring.
            let y = mirror.activate(&x, cfg.gain);
            g.layers[0].train_step(&x, &y, cfg.alpha, cfg.eps);
            mirror.train(&x, &y, cfg.alpha, cfg.eps);
            let got = g.layers[0].support_masked(&x);
            let want = mirror.support(&x);
            if bits(&got) != bits(&want) {
                return Err("support diverged after post-edit training".into());
            }
            Ok(())
        },
    );
}
