//! Batched-EMA trainer pin suite: `train_batch`/`train_sup_batch` and
//! their `_threads` twins vs the sequential per-image trainer, across
//! the whole config registry.
//!
//! Contract (DESIGN.md §3.3):
//!  - **batch of 1 is bitwise** the scalar step: the fold coefficients
//!    degenerate to `(1-α, [α])` exactly and the tile kernel replays
//!    the scalar op order, so feeding images one at a time through the
//!    batched path reproduces `train_unsup_step`/`train_sup_step` to
//!    the bit, registry-wide.
//!  - **full tiles diverge only by the minibatch semantics**: the tile
//!    computes all TILE activities from tile-start weights, so batched
//!    and sequential trajectories differ — but both are convex
//!    combinations of [0,1] inputs anchored at the same p0, so every
//!    trace stays within `1 - (1-α)^N` of its sequential twin after N
//!    images (plus fold-rounding slack).
//!  - **supervised is near-exact**: the hidden stack is frozen during
//!    the head pass, so activities are identical and only the fold's
//!    rounding differs (abs ~1e-4 on traces).
//!  - **threads are deterministic and exact**: `threads = 1` falls
//!    through bitwise; any shard count merges in fixed chunk order, so
//!    repeated runs are bitwise identical, and the merged traces obey
//!    the same EMA bound vs sequential.
//!  - a batched-trained graph **round-trips the v2 checkpoint**
//!    bitwise.

use bcpnn_accel::bcpnn::checkpoint::{load_graph, save_graph};
use bcpnn_accel::bcpnn::{LayerGraph, Projection, StructuralPlasticity};
use bcpnn_accel::config::{by_name, registry, ModelConfig};
use bcpnn_accel::data::synth::{self, Dataset};

fn bits(g: &LayerGraph) -> Vec<u32> {
    let mut out = Vec::new();
    for p in g.layers.iter().chain(std::iter::once(&g.head)) {
        out.extend(p.pi.iter().map(|v| v.to_bits()));
        out.extend(p.pj.iter().map(|v| v.to_bits()));
        out.extend(p.pij.iter().map(|v| v.to_bits()));
        out.extend(p.wij.iter().map(|v| v.to_bits()));
        out.extend(p.bj.iter().map(|v| v.to_bits()));
        out.extend(p.mask_hc.iter().map(|v| v.to_bits()));
    }
    out
}

fn data_for(cfg: &ModelConfig, seed: u64) -> Dataset {
    // Large paper models get a reduced set so the debug-build suite
    // stays fast; the math is per-image, so coverage is unaffected.
    let n = if cfg.n_in() * cfg.n_h() > 1_000_000 { 2 } else { 2 * cfg.batch.clamp(4, 12) };
    synth::generate(cfg.img_side, cfg.n_classes, n, seed, 0.15)
}

/// Sequential-vs-batched EMA drift bound after `n` images (DESIGN.md
/// §3.3): both trajectories are convex combinations of [0,1] inputs
/// anchored at the same p0, so they can differ by at most the total
/// weight the EMA has shifted off p0, `1 - (1-α)^n`, plus rounding
/// slack for the fold.
fn ema_bound(alpha: f32, n: usize) -> f32 {
    (1.0 - (1.0 - alpha as f64).powi(n as i32)) as f32 + 1e-5
}

fn assert_traces_close(name: &str, what: &str, a: &Projection, b: &Projection, tol: f32) {
    for (arr, (x, y)) in [
        ("pi", (&a.pi, &b.pi)),
        ("pj", (&a.pj, &b.pj)),
        ("pij", (&a.pij, &b.pij)),
    ] {
        assert_eq!(x.len(), y.len(), "{name} {what} {arr} len");
        for (k, (u, v)) in x.iter().zip(y.iter()).enumerate() {
            assert!(
                (u - v).abs() <= tol,
                "{name} {what} {arr}[{k}]: {u} vs {v} (tol {tol})"
            );
        }
    }
}

// --- batch of 1 is the scalar step, bitwise, registry-wide ----------

#[test]
fn batch_of_one_is_bitwise_the_scalar_step() {
    for name in registry().keys() {
        let cfg = by_name(name).unwrap();
        let d = data_for(&cfg, 7);
        let mut seq = LayerGraph::new(cfg.clone(), 7);
        let mut bat = LayerGraph::new(cfg, 7);
        for img in &d.images {
            seq.train_unsup_step(img);
            bat.train_batch(std::slice::from_ref(img));
        }
        for (img, &label) in d.images.iter().zip(&d.labels) {
            seq.train_sup_step(img, label as usize);
            bat.train_sup_batch(std::slice::from_ref(img), &[label]);
        }
        assert_eq!(bits(&seq), bits(&bat), "{name}: batch-of-1 drifted from scalar step");
    }
}

// --- full tiles: tolerance-pinned vs sequential, registry-wide ------

#[test]
fn batched_matches_sequential_within_ema_bound() {
    for name in registry().keys() {
        let cfg = by_name(name).unwrap();
        let d = data_for(&cfg, 11);
        let tol = ema_bound(cfg.alpha, d.images.len());
        let mut seq = LayerGraph::new(cfg.clone(), 11);
        let mut bat = LayerGraph::new(cfg, 11);
        for img in &d.images {
            seq.train_unsup_step(img);
        }
        bat.train_batch(&d.images);
        for (l, (a, b)) in seq.layers.iter().zip(bat.layers.iter()).enumerate() {
            assert_traces_close(name, &format!("layer {l}"), a, b, tol);
        }
    }
}

#[test]
fn batched_matches_sequential_post_rewire() {
    // Re-anchor after structural plasticity: rewire a shared warm
    // graph once, then train the clones on; the bound only covers the
    // post-rewire images.
    let cfg = by_name("toy-deep").unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 32, 3, 0.15);
    let mut base = LayerGraph::new(cfg.clone(), 3);
    base.train_batch(&d.images[..16]);
    let sp = StructuralPlasticity::default();
    base.rewire(&sp);

    let mut seq = base.clone();
    let mut bat = base;
    for img in &d.images[16..] {
        seq.train_unsup_step(img);
    }
    bat.train_batch(&d.images[16..]);
    let tol = ema_bound(cfg.alpha, 16);
    for (l, (a, b)) in seq.layers.iter().zip(bat.layers.iter()).enumerate() {
        assert_traces_close("toy-deep", &format!("post-rewire layer {l}"), a, b, tol);
        assert_eq!(
            bits_of(&a.mask_hc),
            bits_of(&b.mask_hc),
            "toy-deep post-rewire layer {l}: masks drifted"
        );
    }
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// --- supervised head: near-exact (frozen hidden stack) --------------

#[test]
fn sup_batched_is_near_exact() {
    let cfg = by_name("toy-deep").unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 24, 9, 0.15);
    let mut warm = LayerGraph::new(cfg, 9);
    warm.train_batch(&d.images);
    let mut seq = warm.clone();
    let mut bat = warm;
    for (img, &label) in d.images.iter().zip(&d.labels) {
        seq.train_sup_step(img, label as usize);
    }
    bat.train_sup_batch(&d.images, &d.labels);
    // Hidden stacks untouched by the head pass: bitwise.
    for (l, (a, b)) in seq.layers.iter().zip(bat.layers.iter()).enumerate() {
        assert_traces_close("toy-deep", &format!("sup hidden layer {l}"), a, b, 0.0);
    }
    // Head activities are identical (frozen stack), so only the fold's
    // summation order differs: rounding-level drift.
    assert_traces_close("toy-deep", "sup head", &seq.head, &bat.head, 1e-4);
    for (k, (u, v)) in seq.head.bj.iter().zip(bat.head.bj.iter()).enumerate() {
        assert!((u - v).abs() <= 1e-3, "sup head bj[{k}]: {u} vs {v}");
    }
}

// --- threads: bitwise fall-through, determinism, and the bound ------

#[test]
fn threads_one_is_bitwise_the_batched_path() {
    for name in ["tiny", "small", "edge", "toy-deep", "mnist-deep2"] {
        let cfg = by_name(name).unwrap();
        let d = data_for(&cfg, 13);
        let mut a = LayerGraph::new(cfg.clone(), 13);
        let mut b = LayerGraph::new(cfg, 13);
        a.train_batch(&d.images);
        b.train_batch_threads(&d.images, 1);
        a.train_sup_batch(&d.images, &d.labels);
        b.train_sup_batch_threads(&d.images, &d.labels, 1);
        assert_eq!(bits(&a), bits(&b), "{name}: threads=1 is not the sequential batched path");
    }
}

#[test]
fn any_thread_count_is_deterministic_and_bounded() {
    let cfg = by_name("toy-deep").unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 40, 17, 0.15);
    let mut seq = LayerGraph::new(cfg.clone(), 17);
    for img in &d.images {
        seq.train_unsup_step(img);
    }
    // 2x the EMA bound: batched-vs-sequential drift plus the merge's
    // re-anchoring of each chunk at the round-start traces.
    let tol = 2.0 * ema_bound(cfg.alpha, d.images.len());
    for threads in [1usize, 2, 3, 5, 8] {
        let mut a = LayerGraph::new(cfg.clone(), 17);
        let mut b = LayerGraph::new(cfg.clone(), 17);
        a.train_batch_threads(&d.images, threads);
        b.train_batch_threads(&d.images, threads);
        assert_eq!(bits(&a), bits(&b), "threads={threads}: nondeterministic merge");
        for (l, (s, p)) in seq.layers.iter().zip(a.layers.iter()).enumerate() {
            assert_traces_close(
                "toy-deep",
                &format!("threads={threads} layer {l}"),
                s,
                p,
                tol,
            );
        }
        a.train_sup_batch_threads(&d.images, &d.labels, threads);
        b.train_sup_batch_threads(&d.images, &d.labels, threads);
        assert_eq!(bits(&a), bits(&b), "threads={threads}: nondeterministic sup merge");
    }
}

// --- checkpoint: batched epoch round-trips the v2 format ------------

#[test]
fn checkpoint_roundtrips_after_batched_epoch() {
    let cfg = by_name("toy-deep").unwrap();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 32, 23, 0.15);
    let mut g = LayerGraph::new(cfg, 23);
    g.train_batch_threads(&d.images, 2);
    g.rewire(&StructuralPlasticity::default());
    g.train_sup_batch_threads(&d.images, &d.labels, 2);

    let path = std::env::temp_dir().join(format!("bcpnn_tb_{}.ckpt", std::process::id()));
    save_graph(&path, &g).unwrap();
    let loaded = load_graph(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(bits(&g), bits(&loaded), "batched-trained graph did not round-trip");
    assert_eq!(loaded.cfg.name, "toy-deep");
}
