//! System tests of the layer-graph refactor.
//!
//! The load-bearing guarantee: a 1-element [`LayerGraph`] is **bitwise
//! identical** to the seed [`Network`] — init, inference, and training
//! — on every single-layer registry config (the seed numerics are the
//! oracle). On top of that, a stacked config must run end to end:
//! reference training, the multi-stage dataflow pipeline, and the
//! pipeline-parallel cluster executor, all agreeing bit for bit.

use std::sync::Arc;
use std::time::Duration;

use bcpnn_accel::bcpnn::{LayerGraph, Network};
use bcpnn_accel::cluster::{plan_pipeline, PipelineParallelExecutor};
use bcpnn_accel::config::registry;
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::{InferenceServer, ServerConfig};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::stream::pipeline::layer_graph_pipeline;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The bitwise-equality oracle, per config: fresh `Network` vs fresh
/// 1-layer `LayerGraph` from the same seed — inference on a few
/// images, then one (size-capped) train batch, then inference again.
fn assert_graph_matches_network(name: &str) {
    let cfg = by_name(name).unwrap();
    assert_eq!(cfg.n_layers(), 1, "{name} is not a single-layer config");
    let seed = 42u64;
    let mut net = Network::new(cfg.clone(), seed);
    let mut graph = LayerGraph::new(cfg.clone(), seed);

    // Identical initial state. (Direct Vec equality: both sides run
    // the same instruction sequence, so equal values are equal bits;
    // no NaNs can arise from ln of positive probabilities.)
    assert_eq!(graph.layers[0].pij, net.params.pij, "{name}: init pij");
    assert_eq!(graph.layers[0].wij, net.params.wij, "{name}: init wij");
    assert_eq!(graph.layers[0].mask_hc, net.params.mask_hc, "{name}: init mask");
    assert_eq!(graph.head.wij, net.params.who, "{name}: init who");

    // Large paper models get a reduced batch so the debug-build test
    // stays fast; the math is per-image, so coverage is unaffected.
    let n_imgs = if cfg.n_in() * cfg.n_h() > 1_000_000 { 2 } else { cfg.batch };
    let d = synth::generate(cfg.img_side, cfg.n_classes, n_imgs.max(4), seed, 0.15);

    for img in &d.images {
        assert_eq!(
            bits(&graph.infer(img)),
            bits(&net.infer(img)),
            "{name}: inference diverges before training"
        );
    }

    // One train batch: unsupervised then supervised, image-parallel.
    for img in d.images.iter().take(n_imgs) {
        net.train_unsup_step(img);
        graph.train_unsup_step(img);
    }
    for (img, &l) in d.images.iter().zip(&d.labels).take(n_imgs) {
        net.train_sup_step(img, l as usize);
        graph.train_sup_step(img, l as usize);
    }

    assert_eq!(graph.layers[0].pi, net.params.pi, "{name}: pi");
    assert_eq!(graph.layers[0].pj, net.params.pj, "{name}: pj");
    assert_eq!(graph.layers[0].pij, net.params.pij, "{name}: pij");
    assert_eq!(graph.layers[0].wij, net.params.wij, "{name}: wij");
    assert_eq!(graph.layers[0].bj, net.params.bj, "{name}: bj");
    assert_eq!(graph.head.pi, net.params.qi, "{name}: qi");
    assert_eq!(graph.head.pj, net.params.qk, "{name}: qk");
    assert_eq!(graph.head.pij, net.params.qik, "{name}: qik");
    assert_eq!(graph.head.wij, net.params.who, "{name}: who");
    assert_eq!(graph.head.bj, net.params.bk, "{name}: bk");

    for img in &d.images {
        assert_eq!(
            bits(&graph.infer(img)),
            bits(&net.infer(img)),
            "{name}: inference diverges after training"
        );
    }
}

#[test]
fn one_layer_graph_bitwise_equals_network_small_configs() {
    for name in ["tiny", "small", "edge"] {
        assert_graph_matches_network(name);
    }
}

#[test]
fn one_layer_graph_bitwise_equals_network_model1() {
    assert_graph_matches_network("model1");
}

#[test]
fn one_layer_graph_bitwise_equals_network_model2() {
    assert_graph_matches_network("model2");
}

#[test]
fn one_layer_graph_bitwise_equals_network_model3() {
    assert_graph_matches_network("model3");
}

#[test]
fn every_registry_config_is_covered_by_the_oracle_or_deep_path() {
    // The bitwise suite above must track the registry: every
    // single-layer config is named in one of the oracle tests, every
    // stacked config exercised by the deep end-to-end tests below.
    let single: Vec<String> = registry()
        .values()
        .filter(|c| c.n_layers() == 1)
        .map(|c| c.name.clone())
        .collect();
    assert_eq!(
        single,
        ["edge", "model1", "model2", "model3", "small", "tiny"]
            .map(String::from)
            .to_vec()
    );
    let deep: Vec<String> = registry()
        .values()
        .filter(|c| c.n_layers() > 1)
        .map(|c| c.name.clone())
        .collect();
    assert_eq!(deep, ["mnist-deep2", "toy-deep"].map(String::from).to_vec());
}

/// A trained deep graph with non-trivial weights in every projection.
fn trained_deep_graph(seed: u64) -> LayerGraph {
    let cfg = by_name("toy-deep").unwrap();
    let mut g = LayerGraph::new(cfg.clone(), seed);
    let d = synth::generate(cfg.img_side, cfg.n_classes, 96, seed, 0.15);
    for _ in 0..2 {
        for img in &d.images {
            g.train_unsup_step(img);
        }
    }
    for (img, &l) in d.images.iter().zip(&d.labels) {
        g.train_sup_step(img, l as usize);
    }
    g
}

#[test]
fn deep_config_trains_and_infers_end_to_end() {
    let g = trained_deep_graph(42);
    let cfg = g.cfg.clone();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 32, 9, 0.15);
    // Valid probability outputs on fresh data.
    for img in &d.images {
        let p = g.infer(img);
        assert_eq!(p.len(), cfg.n_out());
        assert!(p.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
    // The stacked net still learns: no degenerate constant predictor.
    let preds: Vec<usize> = d.images.iter().map(|i| g.predict(i)).collect();
    let first = preds[0];
    assert!(preds.iter().any(|&p| p != first), "constant predictor");
}

#[test]
fn deep_dataflow_pipeline_bitwise_matches_reference() {
    let g = Arc::new(trained_deep_graph(7));
    let cfg = g.cfg.clone();
    let d = synth::generate(cfg.img_side, cfg.n_classes, 24, 3, 0.15);
    let reference: Vec<Vec<u32>> = d.images.iter().map(|i| bits(&g.infer(i))).collect();
    let (out, rep) = layer_graph_pipeline(&g, d.images.clone(), 8);
    assert_eq!(out.len(), reference.len());
    // One support+softmax stage pair per layer in the report.
    let stage_names: Vec<&str> = rep.stages.iter().map(|s| s.name.as_str()).collect();
    for l in 0..cfg.n_layers() {
        assert!(stage_names.contains(&format!("support{l}").as_str()), "{stage_names:?}");
        assert!(stage_names.contains(&format!("softmax{l}").as_str()), "{stage_names:?}");
    }
    for (i, (got, want)) in out.iter().zip(&reference).enumerate() {
        assert_eq!(&bits(got), want, "image {i} diverges in the dataflow pipeline");
    }
}

#[test]
fn deep_cluster_pipeline_executor_bitwise_matches_reference() {
    let g = trained_deep_graph(11);
    let cfg = g.cfg.clone();
    let dev = FpgaDevice::u55c();
    let plan = plan_pipeline(&cfg, KernelVersion::Infer, &dev).unwrap();
    // Per-layer estimator/timing numbers ride on the plan.
    assert_eq!(plan.stages.len(), cfg.n_layers());
    for s in &plan.stages {
        assert!(s.kernel_s > 0.0);
        assert!(s.util.luts > 0);
        assert!(s.hbm_bytes > 0);
    }

    let d = synth::generate(cfg.img_side, cfg.n_classes, 20, 5, 0.15);
    let reference: Vec<Vec<u32>> = d.images.iter().map(|i| bits(&g.infer(i))).collect();
    let exec = PipelineParallelExecutor::new(g, &plan).unwrap();
    let probs = exec.infer_batch(&d.images).unwrap();
    for (i, (got, want)) in probs.iter().zip(&reference).enumerate() {
        assert_eq!(&bits(got), want, "image {i} diverges across devices");
    }
    let reports = exec.shutdown();
    assert_eq!(reports.len(), cfg.n_layers());
    for r in &reports {
        assert_eq!(r.items, d.images.len() as u64, "stage {}", r.stage);
    }
}

#[test]
fn serving_layer_drives_pipeline_parallel_backend() {
    // The generic batching server with a deep pipeline-parallel
    // backend: the full serving story for stacked configs.
    let g = trained_deep_graph(13);
    let cfg = g.cfg.clone();
    let plan = plan_pipeline(&cfg, KernelVersion::Infer, &FpgaDevice::u55c()).unwrap();
    let server = InferenceServer::start(
        move || PipelineParallelExecutor::new(g, &plan),
        ServerConfig {
            queue_depth: 64,
            flush_timeout: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let d = synth::generate(cfg.img_side, cfg.n_classes, 30, 8, 0.15);
    let handles: Vec<_> = d
        .images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &handles {
        let probs = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(probs.len(), cfg.n_out());
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, 30);
}
