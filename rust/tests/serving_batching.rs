//! Edge-behavior tests the cluster layer depends on:
//!
//! - the `coordinator::server` dynamic batcher's flush-timeout path,
//!   pinned with a mock [`InferBackend`] (no PJRT artifacts needed);
//! - `stream::fifo` backpressure/stats corners (close/drain,
//!   try_recv accounting, stall counters under multiple writers).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use bcpnn_accel::coordinator::{InferBackend, InferenceServer, ServerConfig};
use bcpnn_accel::stream::{Fifo, RecvError};

/// Scriptable backend: records per-call batch sizes, optionally fails.
#[derive(Clone)]
struct MockBackend {
    batch: usize,
    calls: Arc<Mutex<Vec<usize>>>,
    fail: Arc<AtomicBool>,
}

impl MockBackend {
    fn new(batch: usize) -> MockBackend {
        MockBackend {
            batch,
            calls: Arc::new(Mutex::new(Vec::new())),
            fail: Arc::new(AtomicBool::new(false)),
        }
    }
}

impl InferBackend for MockBackend {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer_batch(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.calls.lock().unwrap().push(images.len());
        if self.fail.load(Ordering::SeqCst) {
            anyhow::bail!("mock backend failure");
        }
        Ok(images.iter().map(|img| vec![img[0]]).collect())
    }
}

fn start(mock: MockBackend, flush: Duration) -> InferenceServer {
    let queue_depth = 64;
    InferenceServer::start(
        move || Ok(mock),
        ServerConfig { queue_depth, flush_timeout: flush, ..ServerConfig::default() },
    )
    .unwrap()
}

#[test]
fn partial_batch_flushes_on_timeout() {
    // 3 requests against batch=8: only the flush timer can dispatch.
    let mock = MockBackend::new(8);
    let calls = mock.calls.clone();
    let flush = Duration::from_millis(40);
    let server = start(mock, flush);

    let t0 = Instant::now();
    let handles: Vec<_> = (0..3)
        .map(|i| server.submit(vec![i as f32]).unwrap())
        .collect();
    for (i, rx) in handles.iter().enumerate() {
        let p = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(p, vec![i as f32]); // responses matched to requests
    }
    let waited = t0.elapsed();
    // Responses arrived while the queue was still OPEN (no shutdown
    // yet), i.e. via the timeout flush — and only after the flush
    // window elapsed.
    assert!(waited >= Duration::from_millis(30), "{waited:?}");
    assert_eq!(*calls.lock().unwrap(), vec![3usize]);

    let rep = server.shutdown();
    assert_eq!(rep.served, 3);
    assert_eq!(rep.batches, 1);
    assert!((rep.mean_fill - 3.0).abs() < 1e-9);
}

#[test]
fn full_batch_dispatches_without_waiting_for_flush() {
    // flush = 10s: if the batcher (wrongly) waited for the timer, the
    // 2s receive timeouts below would trip.
    let mock = MockBackend::new(4);
    let calls = mock.calls.clone();
    let server = start(mock, Duration::from_secs(10));

    let handles: Vec<_> = (0..8)
        .map(|i| server.submit(vec![i as f32]).unwrap())
        .collect();
    for rx in &handles {
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
    }
    assert_eq!(*calls.lock().unwrap(), vec![4usize, 4]);
    let rep = server.shutdown();
    assert_eq!(rep.served, 8);
    assert_eq!(rep.batches, 2);
    assert!((rep.mean_fill - 4.0).abs() < 1e-9);
}

#[test]
fn backend_failure_closes_response_channels() {
    let mock = MockBackend::new(4);
    mock.fail.store(true, Ordering::SeqCst);
    let server = start(mock, Duration::from_millis(5));
    let rx1 = server.submit(vec![1.0]).unwrap();
    let rx2 = server.submit(vec![2.0]).unwrap();
    // Clients see disconnected channels, not hangs.
    assert!(rx1.recv_timeout(Duration::from_secs(10)).is_err());
    assert!(rx2.recv_timeout(Duration::from_secs(10)).is_err());
    let rep = server.shutdown();
    assert_eq!(rep.served, 0);
    assert!(rep.batches >= 1);
    assert_eq!(rep.latency.count, 0);
}

#[test]
fn graph_backend_serves_tile_engine_bitwise_with_threads() {
    use bcpnn_accel::bcpnn::LayerGraph;
    use bcpnn_accel::config::by_name;
    use bcpnn_accel::coordinator::GraphBackend;
    use bcpnn_accel::data::synth;

    let cfg = by_name("tiny").unwrap();
    let g = LayerGraph::new(cfg.clone(), 77);
    let d = synth::generate(cfg.img_side, cfg.n_classes, 19, 4, 0.15);
    let reference: Vec<Vec<f32>> = d.images.iter().map(|i| g.infer(i)).collect();

    for threads in [1usize, 3] {
        let backend = GraphBackend::new(g.clone(), threads);
        // Direct dispatch: the collected batch goes through the tile
        // engine (+ splitter) and must match per-image inference bit
        // for bit.
        let got = bcpnn_accel::coordinator::InferBackend::infer_batch(&backend, &d.images)
            .unwrap();
        assert_eq!(got, reference, "{threads} threads");
        // Shape validation still guards the serving edge.
        let err = bcpnn_accel::coordinator::InferBackend::infer_batch(
            &backend,
            &[vec![0.5; 3]],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("pixels"), "{err}");

        // Behind the real server: responses identical, thread count
        // surfaced in the report.
        let server = InferenceServer::start(
            move || Ok(backend),
            ServerConfig {
                queue_depth: 64,
                flush_timeout: Duration::from_millis(2),
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let pending: Vec<_> = d
            .images
            .iter()
            .map(|img| server.submit(img.clone()).unwrap())
            .collect();
        for (rx, want) in pending.iter().zip(&reference) {
            let probs = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(&probs, want);
        }
        let rep = server.shutdown();
        assert_eq!(rep.served, d.images.len() as u64);
        assert_eq!(rep.threads, threads);
    }
}

// ---------------------------------------------------- fifo edge cases

#[test]
fn try_recv_accounts_pops_but_never_stalls() {
    let f: Fifo<u32> = Fifo::with_capacity(2);
    assert_eq!(f.try_recv(), None);
    assert_eq!(f.try_recv(), None);
    let s = f.stats();
    assert_eq!(s.read_stalls, 0, "try_recv must not count as a stall");
    assert_eq!(s.pops, 0);

    f.send(7).unwrap();
    assert_eq!(f.try_recv(), Some(7));
    let s = f.stats();
    assert_eq!(s.pops, 1);
    assert_eq!(s.pushes, 1);
}

#[test]
fn send_to_closed_fifo_returns_value_uncounted() {
    let f: Fifo<String> = Fifo::with_capacity(4);
    f.send("a".into()).unwrap();
    f.close();
    // The rejected value comes back to the caller...
    assert_eq!(f.send("b".into()), Err("b".to_string()));
    // ...and is not counted as a push.
    assert_eq!(f.stats().pushes, 1);
    // Draining after close still works, then errors.
    assert_eq!(f.recv(), Ok("a".to_string()));
    assert_eq!(f.recv(), Err(RecvError));
    assert_eq!(f.stats().read_stalls, 0, "closed-empty recv is not a stall");
}

#[test]
fn each_blocked_writer_counts_a_stall() {
    let f: Fifo<u32> = Fifo::with_capacity(1);
    f.send(0).unwrap();
    let writers: Vec<_> = (1..=2u32)
        .map(|v| {
            let f = f.clone();
            thread::spawn(move || f.send(v).unwrap())
        })
        .collect();
    // Wait until both writers have actually blocked on the full FIFO
    // (bounded poll instead of a fixed sleep: robust on loaded CI).
    let deadline = Instant::now() + Duration::from_secs(5);
    while f.stats().write_stalls < 2 && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(f.stats().write_stalls, 2);
    assert_eq!(f.len(), 1);
    // Drain three values; order of the two blocked writers is
    // unspecified but nothing is lost.
    let mut got = vec![f.recv().unwrap()];
    got.push(f.recv().unwrap());
    got.push(f.recv().unwrap());
    for w in writers {
        w.join().unwrap();
    }
    got.sort_unstable();
    assert_eq!(got, vec![0, 1, 2]);
    let s = f.stats();
    assert_eq!(s.pushes, 3);
    assert_eq!(s.pops, 3);
}

#[test]
fn high_water_never_exceeds_capacity_under_pressure() {
    let f: Fifo<u64> = Fifo::with_capacity(3);
    let tx = f.clone();
    let producer = thread::spawn(move || {
        for i in 0..100u64 {
            tx.send(i).unwrap();
        }
        tx.close();
    });
    let mut n = 0u64;
    while f.recv().is_ok() {
        n += 1;
    }
    producer.join().unwrap();
    assert_eq!(n, 100);
    let s = f.stats();
    assert!(s.high_water <= 3, "high water {} > capacity", s.high_water);
    assert!(s.high_water >= 1);
    assert_eq!(s.pushes, 100);
    assert_eq!(s.pops, 100);
}

#[test]
fn close_is_idempotent_and_sticky() {
    let f: Fifo<u8> = Fifo::with_capacity(1);
    assert!(!f.is_closed());
    f.close();
    f.close();
    assert!(f.is_closed());
    assert_eq!(f.send(1), Err(1));
    assert_eq!(f.recv(), Err(RecvError));
}
