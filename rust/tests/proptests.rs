//! Property-based tests on coordinator/substrate invariants, using the
//! in-crate `testing::prop_check` helper (deterministic xorshift-driven
//! cases; failing seeds are reported for reproduction).

use bcpnn_accel::bcpnn::{Network, Params, StructuralPlasticity};
use bcpnn_accel::config::{by_name, ModelConfig};
use bcpnn_accel::data::rng::XorShift64;
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::fpga::{estimator, timing};
use bcpnn_accel::stream::depth::{simulate, StageSpec};
use bcpnn_accel::stream::Fifo;
use bcpnn_accel::testing::{prob_vec, prop_check, uniform};

fn random_config(rng: &mut XorShift64) -> ModelConfig {
    let mut cfg = by_name("tiny").unwrap();
    cfg.name = "prop".into();
    cfg.img_side = 4 + rng.next_range(8); // 4..11
    cfg.hc_h = 1 + rng.next_range(6);
    cfg.mc_h = 2 + rng.next_range(15);
    cfg.n_classes = 2 + rng.next_range(5);
    cfg.nact_hi = 1 + rng.next_range(cfg.hc_in());
    cfg.alpha = uniform(rng, 1e-3, 0.3);
    cfg.validate().unwrap();
    cfg
}

#[test]
fn prop_hidden_activity_is_distribution() {
    prop_check(
        "hidden-activity-distribution",
        0xA1,
        25,
        |rng| {
            let cfg = random_config(rng);
            let seed = rng.next_u64();
            let img: Vec<f32> = (0..cfg.hc_in()).map(|_| rng.next_f32()).collect();
            (cfg, seed, img)
        },
        |(cfg, seed, img)| {
            let net = Network::new(cfg.clone(), *seed);
            let (_, y) = net.hidden_activity(img);
            for (h, hc) in y.chunks(cfg.mc_h).enumerate() {
                let s: f32 = hc.iter().sum();
                if (s - 1.0).abs() > 1e-4 {
                    return Err(format!("HC {h} sums to {s}"));
                }
                if hc.iter().any(|v| !v.is_finite() || *v < 0.0) {
                    return Err(format!("HC {h} has invalid probs"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_traces_stay_in_unit_interval_under_training() {
    prop_check(
        "traces-unit-interval",
        0xB2,
        15,
        |rng| {
            let cfg = random_config(rng);
            let seed = rng.next_u64();
            (cfg, seed)
        },
        |(cfg, seed)| {
            let mut net = Network::new(cfg.clone(), *seed);
            let d = synth::generate(cfg.img_side, cfg.n_classes, 30, *seed, 0.2);
            for img in &d.images {
                net.train_unsup_step(img);
            }
            let p = &net.params;
            for (name, arr) in [("pi", &p.pi), ("pj", &p.pj), ("pij", &p.pij)] {
                if arr.iter().any(|&v| v <= 0.0 || v >= 1.0) {
                    return Err(format!("{name} left (0,1)"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rewiring_preserves_sparsity() {
    prop_check(
        "rewire-sparsity",
        0xC3,
        10,
        |rng| {
            let cfg = random_config(rng);
            let seed = rng.next_u64();
            (cfg, seed)
        },
        |(cfg, seed)| {
            let mut net = Network::new(cfg.clone(), *seed);
            let d = synth::generate(cfg.img_side, cfg.n_classes, 40, *seed, 0.2);
            for img in &d.images {
                net.train_unsup_step(img);
            }
            let sp = StructuralPlasticity::default();
            for _ in 0..5 {
                sp.rewire(&mut net.params, cfg);
            }
            for h in 0..cfg.hc_h {
                let active: f32 = (0..cfg.hc_in())
                    .map(|i| net.params.mask_hc[i * cfg.hc_h + h])
                    .sum();
                if active as usize != cfg.nact_hi {
                    return Err(format!(
                        "HC {h}: {} active != nact_hi {}",
                        active, cfg.nact_hi
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_params_roundtrip_mask_expansion() {
    prop_check(
        "mask-expansion-consistent",
        0xD4,
        20,
        |rng| {
            let cfg = random_config(rng);
            let seed = rng.next_u64();
            (cfg, seed)
        },
        |(cfg, seed)| {
            let p = Params::init(cfg, *seed);
            let m = p.expand_mask(cfg);
            let n_h = cfg.n_h();
            for i in (0..cfg.n_in()).step_by(3) {
                for j in (0..n_h).step_by(5) {
                    let hc = p.mask_hc[(i / cfg.mc_in) * cfg.hc_h + j / cfg.mc_h];
                    if m[i * n_h + j] != hc {
                        return Err(format!("mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_estimator_monotone_in_model_size() {
    prop_check(
        "estimator-monotone",
        0xE5,
        20,
        |rng| (random_config(rng),),
        |(cfg,)| {
            let dev = FpgaDevice::u55c();
            let i = estimator::estimate(cfg, KernelVersion::Infer, &dev);
            let t = estimator::estimate(cfg, KernelVersion::Train, &dev);
            let s = estimator::estimate(cfg, KernelVersion::Struct, &dev);
            if !(i.luts <= t.luts && t.luts <= s.luts) {
                return Err("LUT ordering broken".into());
            }
            if !(i.brams <= t.brams && t.brams <= s.brams) {
                return Err("BRAM ordering broken".into());
            }
            let mut bigger = cfg.clone();
            bigger.mc_h *= 2;
            let t2 = estimator::estimate(&bigger, KernelVersion::Train, &dev);
            if t2.brams < t.brams {
                return Err("BRAM decreased with larger hidden layer".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_latency_positive_and_ordered() {
    prop_check(
        "latency-ordered",
        0xF6,
        20,
        |rng| (random_config(rng),),
        |(cfg,)| {
            let dev = FpgaDevice::u55c();
            let i = timing::latency_ms(cfg, KernelVersion::Infer, &dev);
            let t = timing::latency_ms(cfg, KernelVersion::Train, &dev);
            if !(i > 0.0 && t > 0.0) {
                return Err("non-positive latency".into());
            }
            if t < i {
                return Err(format!("train {t} faster than infer {i}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fifo_preserves_sequence_under_random_ops() {
    prop_check(
        "fifo-sequence",
        0x17,
        30,
        |rng| {
            let n = 1 + rng.next_range(200);
            let cap = 1 + rng.next_range(16);
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            (vals, cap)
        },
        |(vals, cap)| {
            let f = Fifo::with_capacity(*cap);
            let tx = f.clone();
            let vals2 = vals.clone();
            let h = std::thread::spawn(move || {
                for v in vals2 {
                    tx.send(v).unwrap();
                }
                tx.close();
            });
            let mut got = Vec::new();
            while let Ok(v) = f.recv() {
                got.push(v);
            }
            h.join().unwrap();
            if &got != vals {
                return Err("order or content not preserved".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_depth_sim_deeper_never_slower() {
    prop_check(
        "depth-monotone",
        0x28,
        15,
        |rng| {
            let n_stages = 2 + rng.next_range(4);
            let stages: Vec<StageSpec> = (0..n_stages)
                .map(|i| {
                    StageSpec::streaming(&format!("s{i}"), 1 + rng.next_range(8) as u64)
                })
                .collect();
            let depths: Vec<usize> =
                (0..n_stages - 1).map(|_| 1 + rng.next_range(8)).collect();
            let items = 20 + rng.next_range(60) as u64;
            (stages, depths, items)
        },
        |(stages, depths, items)| {
            let shallow = simulate(stages, depths, *items);
            let deep: Vec<usize> = depths.iter().map(|d| d * 4).collect();
            let deeper = simulate(stages, &deep, *items);
            if deeper.total_cycles > shallow.total_cycles {
                return Err(format!(
                    "deeper FIFOs slower: {} > {}",
                    deeper.total_cycles, shallow.total_cycles
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prob_vec_valid() {
    prop_check(
        "prob-vec",
        0x39,
        50,
        |rng| {
            let n = 1 + rng.next_range(64);
            prob_vec(rng, n)
        },
        |v| {
            let s: f32 = v.iter().sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(format!("sum {s}"));
            }
            Ok(())
        },
    );
}
