//! Vendored stub of the `xla` crate (xla-rs) PJRT API surface.
//!
//! The build environment has no native `xla_extension` library, so this
//! crate provides the exact types/signatures `runtime::session` links
//! against, failing *late and loudly*: clients construct, HLO text
//! parses (the file is read and minimally validated), but `compile()`
//! reports that the PJRT runtime is unavailable. Callers gate on built
//! artifacts (`artifacts/manifest.json`), so the PJRT-backed paths are
//! skipped cleanly in environments where this stub is in play; swapping
//! the real `xla = "0.1.6"` back in requires no source change.

use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// Error type matching xla-rs (implements `std::error::Error`, so `?`
/// converts into `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT runtime unavailable: bcpnn-accel was built against the \
     vendored xla stub (no native xla_extension in this environment)";

/// Element types uploadable to device buffers.
pub trait ElementType: Copy + 'static {
    const DTYPE: &'static str;
}

impl ElementType for f32 {
    const DTYPE: &'static str = "f32";
}

impl ElementType for i32 {
    const DTYPE: &'static str = "i32";
}

/// A PJRT device handle (opaque; only used as an `Option<&PjRtDevice>`
/// argument default in this workspace).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A PJRT client. The stub constructs successfully (cheap, no native
/// code) so that session setup errors point at the first operation that
/// actually needs the runtime.
#[derive(Clone)]
pub struct PjRtClient {
    platform: Arc<String>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: Arc::new("stub-cpu".to_string()) })
    }

    pub fn platform_name(&self) -> String {
        self.platform.as_ref().clone()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::new(STUB_MSG))
    }
}

/// Parsed HLO module (text form). The stub validates the file exists
/// and is non-empty so path errors surface with real diagnostics.
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path:?}: {e}")))?;
        if text.trim().is_empty() {
            return Err(Error::new(format!("empty HLO module {path:?}")));
        }
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable. Unconstructible through the stub (compile
/// always errors); methods exist for type-checking only.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _inputs: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

/// A device-resident buffer. Unconstructible through the stub.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB_MSG))
    }
}

/// A host-side literal value. Unconstructible through the stub.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new(STUB_MSG))
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(Error::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_compile_fails() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let missing = HloModuleProto::from_text_file("/nonexistent/x.hlo.txt");
        assert!(missing.is_err());
        let err = c
            .compile(&XlaComputation { _private: () })
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("unavailable"), "{err}");
    }

    #[test]
    fn error_is_std_error() {
        fn takes_std<E: std::error::Error>(_: E) {}
        takes_std(Error::new("x"));
    }
}
