//! Vendored drop-in subset of the `anyhow` crate (offline build
//! environment — no crates.io access).
//!
//! Implements exactly the surface this workspace uses, with the same
//! semantics as upstream anyhow:
//!
//! - [`Error`]: an opaque error carrying a context chain. `Display`
//!   shows the outermost message; `{:#}` joins the chain outermost
//!   first with `": "`; `Debug` shows the chain as `Caused by:` lines.
//! - [`Result<T>`] alias with the error type defaulted.
//! - `?` conversion from any `std::error::Error + Send + Sync + 'static`
//!   (possible precisely because [`Error`] itself does *not* implement
//!   `std::error::Error`, mirroring upstream).
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context messages.
///
/// `chain[0]` is the outermost (most recently attached) context; the
/// last element is the original error message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach a new outermost context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The original (innermost) error message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement std::error::Error, so this
// blanket conversion cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to errors (on `Result`) or turn `None` into an error
/// (on `Option`).
pub trait Context<T>: Sized {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Err::<(), _>(io_err())
            .context("opening config")
            .unwrap_err();
        assert_eq!(e.to_string(), "opening config");
    }

    #[test]
    fn alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("opening config")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            let _ = std::str::from_utf8(&[0xFF])?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_expand() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert!(f(50).unwrap_err().to_string().contains("50"));
        let e = anyhow!("plain {}", "message");
        assert_eq!(e.root_cause(), "plain message");
        assert_eq!(e.chain().count(), 1);
    }
}
