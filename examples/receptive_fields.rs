//! Fig. 5 reproduction: structural plasticity reshapes a hidden
//! hypercolumn's receptive field from random to focused on the
//! informative pixels.
//!
//! Trains the BCPNN with host-side MI rewiring interleaved (the
//! paper's host/device split), snapshotting one HC's receptive field
//! over time. Prints ASCII renderings and writes PGM images under
//! `out/receptive_fields/`.
//!
//!     cargo run --release --example receptive_fields -- --config tiny

use std::fs;
use std::path::PathBuf;

use anyhow::Result;

use bcpnn_accel::bcpnn::structural::receptive_field;
use bcpnn_accel::bcpnn::{Network, StructuralPlasticity};
use bcpnn_accel::config::{by_name, dataset_spec};
use bcpnn_accel::data::synth;
use bcpnn_accel::report::ascii_field;
use bcpnn_accel::util::cli::Args;

fn write_pgm(path: &PathBuf, field: &[f64], side: usize) -> Result<()> {
    let max = field.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let mut buf = format!("P2\n{side} {side}\n255\n");
    for v in field {
        buf.push_str(&format!("{} ", ((v / max).clamp(0.0, 1.0) * 255.0) as u8));
    }
    buf.push('\n');
    fs::write(path, buf)?;
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let name = args.get_or("config", "tiny").to_string();
    let cfg = by_name(&name)?;
    let snapshots: usize = args.get_parse("snapshots", 5usize)?;
    let hc: usize = args.get_parse("hc", 0usize)?;
    let seed: u64 = args.get_parse("seed", 42u64)?;
    let spec = dataset_spec(&name);

    println!("== Fig 5: receptive-field evolution under structural plasticity ==");
    println!("config {name}, hidden HC {hc}, {} snapshots\n", snapshots);

    let out_dir = PathBuf::from("out/receptive_fields");
    fs::create_dir_all(&out_dir)?;

    let mut net = Network::new(cfg.clone(), seed);
    let data = synth::generate(cfg.img_side, cfg.n_classes, spec.train, seed, 0.15);
    let sp = StructuralPlasticity::default();

    // Initial (random) field — Fig. 5 left.
    let rf0 = receptive_field(&net.params, &cfg, hc);
    println!("initial (random wiring):");
    println!("{}", ascii_field(&rf0, cfg.img_side));
    write_pgm(&out_dir.join("rf_000.pgm"), &rf0, cfg.img_side)?;

    let total = spec.train * spec.epochs.max(1);
    let per_snap = total / snapshots;
    let mut active_mi_log = Vec::new();
    for snap in 0..snapshots {
        for i in 0..per_snap {
            let img = &data.images[(snap * per_snap + i) % data.len()];
            net.train_unsup_step(img);
            if (i + 1) % 64 == 0 {
                sp.rewire(&mut net.params, &cfg);
                net.refresh_mask();
            }
        }
        let rf = receptive_field(&net.params, &cfg, hc);
        let mi_sum: f64 = rf.iter().sum();
        active_mi_log.push(mi_sum);
        println!("after {} images (sum MI of active field: {:.4}):",
                 (snap + 1) * per_snap, mi_sum);
        println!("{}", ascii_field(&rf, cfg.img_side));
        write_pgm(&out_dir.join(format!("rf_{:03}.pgm", snap + 1)), &rf, cfg.img_side)?;
    }

    println!("MI captured by the active field over time (should rise):");
    println!("  {:?}", active_mi_log.iter().map(|v| (v * 1e4).round() / 1e4).collect::<Vec<_>>());
    println!("\nPGM snapshots written to {out_dir:?}");
    Ok(())
}
