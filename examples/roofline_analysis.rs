//! Fig. 6 reproduction + exploration: the FPGA roofline (Eqs. 2-5)
//! with the operating points of every built-in model, plus a what-if
//! sweep over kernel frequency and HBM partitioning that shows where
//! the design's headroom is.
//!
//!     cargo run --release --example roofline_analysis

use anyhow::Result;

use bcpnn_accel::config::registry;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::fpga::hbm::HbmModel;
use bcpnn_accel::report;
use bcpnn_accel::roofline;

fn main() -> Result<()> {
    let dev = FpgaDevice::u55c();

    println!("== Fig 6: roofline analysis ({}) ==\n", dev.name);
    println!(
        "Eq.4  B_HBM  = {:.1} GB/s  (32 ch x 256 b x 450 MHz)",
        dev.hbm_bandwidth() / 1e9
    );
    println!(
        "Eq.3  C_FPGA = {:.2} GF/s at 100 MHz (paper: 288.77 GF/s)",
        roofline::peak_compute_flops(&dev, 100e6) / 1e9
    );
    println!(
        "Eq.5  M_b    = {:.3} FLOP/byte at 100 MHz\n",
        roofline::machine_balance(&dev, 100e6)
    );

    // The paper's Fig 6 table (train + struct builds of models 1-3).
    println!("{}", report::fig6(&["model1", "model2", "model3"])?);

    // Roofline curve series (for plotting): attainable GF/s vs AI at
    // the three train-build frequencies.
    println!("roofline series (AI, attainable GF/s) per frequency:");
    for mhz in [60.0, 110.0, 150.0] {
        print!("  {mhz:>5.0} MHz:");
        for ai in [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
            let gf = roofline::attainable_flops(&dev, mhz * 1e6, ai) / 1e9;
            print!(" ({ai},{gf:.0})");
        }
        println!();
    }

    // What-if: how the operating point moves with HBM partitioning —
    // the knob Fig. 4 is about.
    println!("\nHBM partition sweep (model1 train): floats/cycle and stream GB/s at 150 MHz");
    for p in [1u32, 2, 4, 8] {
        let m = HbmModel { partitions: p, burst_bits: 512, kernel_freq_hz: 150e6 };
        println!(
            "  p={p}: {:>3} floats/cycle, {:>6.1} GB/s{}",
            m.floats_per_cycle(),
            m.stream_bandwidth(&dev) / 1e9,
            if p == 4 { "   <- paper's choice (64-float packets)" } else { "" }
        );
    }

    // All built-in configs, for completeness.
    println!("\nall configs (train build):");
    println!("config   AI(F/B)  attained(GF/s)  % of own roof");
    for (name, cfg) in registry() {
        let op = roofline::operating_point(&cfg, KernelVersion::Train, &dev);
        println!(
            "{name:<8} {:>6.3}  {:>13.2}  {:>6.1}%",
            op.ai,
            op.attained_flops / 1e9,
            100.0 * op.efficiency()
        );
    }
    Ok(())
}
