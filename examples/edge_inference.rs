//! Edge inference serving — the deployment scenario the paper's
//! inference-only kernel targets ("particularly beneficial for
//! energy-sensitive edge deployments").
//!
//! Trains briefly, then serves a stream of requests through the
//! dynamic-batching inference server, reporting latency percentiles,
//! throughput, batching efficiency, and the projected on-FPGA
//! latency/energy for the same workload from the device model.
//!
//!     cargo run --release --example edge_inference -- --config edge

use std::time::{Duration, Instant};

use anyhow::Result;

use bcpnn_accel::config::{by_name, dataset_spec};
use bcpnn_accel::coordinator::{Driver, InferenceServer, ServerConfig, TrainOptions};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::fpga::{power, timing};
use bcpnn_accel::runtime::Session;
use bcpnn_accel::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let name = args.get_or("config", "edge").to_string();
    let cfg = by_name(&name)?;
    let n_requests: usize = args.get_parse("requests", 1024usize)?;
    let seed: u64 = args.get_parse("seed", 42u64)?;
    let spec = dataset_spec(&name);

    println!("== edge inference serving ({name}) ==");

    // Phase 1: train the model (full session), then hand the trained
    // parameters to a fresh infer-only server — mirroring the paper's
    // flow of deploying a trained network into the inference build.
    let session = Session::load(std::path::Path::new("artifacts"), &name)?;
    let mut driver = Driver::new(session, &name, seed)?;
    let data = synth::generate(cfg.img_side, cfg.n_classes, spec.train + spec.test, seed, 0.15);
    let (train, test) = data.split(spec.train);
    let out = driver.train(
        &train,
        &test,
        &TrainOptions { epochs: spec.epochs.min(3), ..Default::default() },
    )?;
    println!(
        "trained: {:.1}% test accuracy ({} epochs)",
        out.test_acc * 100.0,
        spec.epochs.min(3)
    );
    let trained = driver.params.clone();

    // Phase 2: serve. The server thread owns its own session (PJRT
    // handles are not Send); we inject the trained parameters.
    let name2 = name.clone();
    let server = InferenceServer::start(
        move || {
            let session =
                Session::load_modes(std::path::Path::new("artifacts"), &name2, &["infer"])?;
            let mut d = Driver::new(session, &name2, seed)?;
            d.set_params(trained);
            Ok(d)
        },
        ServerConfig {
            queue_depth: 256,
            flush_timeout: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )?;

    let reqs = synth::generate(cfg.img_side, cfg.n_classes, n_requests, seed + 1, 0.15);
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(n_requests);
    for img in &reqs.images {
        handles.push(server.submit(img.clone())?);
    }
    let mut correct = 0usize;
    for (rx, &label) in handles.iter().zip(&reqs.labels) {
        let probs = rx.recv_timeout(Duration::from_secs(60))?;
        let pred = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        if pred as u32 == label {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let rep = server.shutdown();

    println!("\nserved {} requests in {:.2}s  ({:.0} req/s)",
             rep.served, wall.as_secs_f64(), rep.served as f64 / wall.as_secs_f64());
    println!("batches: {} (mean fill {:.1}/{})", rep.batches, rep.mean_fill, cfg.batch);
    println!(
        "latency: mean {:.3} ms  p50 {:.3}  p99 {:.3}  max {:.3}",
        rep.latency.mean_ms, rep.latency.p50_ms, rep.latency.p99_ms, rep.latency.max_ms
    );
    println!("accuracy under serving: {:.1}%", 100.0 * correct as f64 / n_requests as f64);

    // Device-model projection for the same workload on the U55C.
    let dev = FpgaDevice::u55c();
    let f_ms = timing::latency_ms(&cfg, KernelVersion::Infer, &dev);
    let f_w = power::power_watts(&cfg, KernelVersion::Infer, &dev);
    println!("\nU55C projection (infer build): {:.3} ms/img, {:.1} W, {:.2} mJ/img",
             f_ms, f_w, f_ms * f_w);
    Ok(())
}
