//! Quickstart — the end-to-end driver (DESIGN.md "End-to-end
//! validation"): trains a full BCPNN through the real three-layer
//! stack (Pallas kernels -> JAX model -> AOT HLO -> PJRT from rust),
//! logging the accuracy curve per epoch, then evaluates and reports
//! per-image latencies.
//!
//!     make artifacts && cargo run --release --example quickstart
//!     # options: --config small --epochs 5 --struct --seed 7
//!
//! The run recorded in EXPERIMENTS.md §E2E used the defaults.

use anyhow::Result;

use bcpnn_accel::config::{by_name, dataset_spec};
use bcpnn_accel::coordinator::{Driver, TrainOptions};
use bcpnn_accel::data::synth;
use bcpnn_accel::runtime::Session;
use bcpnn_accel::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["struct"])?;
    let name = args.get_or("config", "small").to_string();
    let cfg = by_name(&name)?;
    let spec = dataset_spec(&name);
    let epochs: usize = args.get_parse("epochs", spec.epochs)?;
    let seed: u64 = args.get_parse("seed", 42u64)?;

    println!("== BCPNN quickstart ==");
    println!(
        "config {name}: {}x{} input, {}x{} hidden, {} classes, nactHi {}",
        cfg.img_side, cfg.img_side, cfg.hc_h, cfg.mc_h, cfg.n_classes, cfg.nact_hi
    );

    let t0 = std::time::Instant::now();
    let session = Session::load(std::path::Path::new("artifacts"), &name)?;
    println!(
        "artifacts compiled on {} in {:.2}s (python is done — rust only from here)",
        session.platform(),
        t0.elapsed().as_secs_f64()
    );
    let mut driver = Driver::new(session, &name, seed)?;

    let data = synth::generate(
        cfg.img_side, cfg.n_classes, spec.train + spec.test, seed, 0.15,
    );
    let (train, test) = data.split(spec.train);
    println!("data: {} train / {} test synthetic images\n", train.len(), test.len());

    // Epoch loop with an accuracy curve: train one epoch at a time so
    // we can log the curve (the paper's semi-unsupervised protocol:
    // unsupervised epochs, then one supervised pass).
    let structural = args.flag("struct");
    println!("epoch  unsup_ms/img  train_acc  test_acc");
    let mut last = None;
    for e in 1..=epochs {
        let out = driver.train(
            &train,
            &test,
            &TrainOptions { epochs: 1, structural, struct_interval: 4, seed, threads: 1 },
        )?;
        println!(
            "{e:>5}  {:>12.3}  {:>8.1}%  {:>7.1}%",
            out.unsup.mean_ms,
            out.train_acc * 100.0,
            out.test_acc * 100.0
        );
        last = Some(out);
    }

    let out = last.expect("at least one epoch");
    println!("\nfinal: train {:.1}%  test {:.1}%  (chance {:.1}%)",
             out.train_acc * 100.0, out.test_acc * 100.0,
             100.0 / cfg.n_classes as f64);
    println!(
        "per-image latency: unsup {:.3} ms  sup {:.3} ms  infer {:.3} ms (p99 {:.3} ms)",
        out.unsup.mean_ms, out.sup.mean_ms, out.infer.mean_ms, out.infer.p99_ms
    );
    if structural {
        println!(
            "structural plasticity: {} rewires, {} swaps, {:.3}s host time",
            out.rewire_passes, out.rewire_swaps, out.struct_host_s
        );
    }
    Ok(())
}
