//! Cluster serving demo: replicated sharded BCPNN inference with
//! scheduling and a mid-stream device failure.
//!
//!     cargo run --release --example cluster_serve -- \
//!         --config small --replicas 3 --shards 2 --requests 512 \
//!         --policy least --fail 1
//!
//! Trains briefly (host network), deploys the trained parameters to
//! every replica, streams requests through the cluster coordinator,
//! kills one replica halfway, and prints the per-replica / per-shard
//! report: the scale-out path the single-device `serve` command grows
//! into.

use std::time::Duration;

use anyhow::Result;
use bcpnn_accel::bcpnn::Network;
use bcpnn_accel::cluster::{ClusterConfig, ClusterServer, SchedulePolicy};
use bcpnn_accel::config::by_name;
use bcpnn_accel::data::synth;
use bcpnn_accel::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let name = args.get_or("config", "small").to_string();
    let cfg = by_name(&name)?;
    let replicas: usize = args.get_parse("replicas", 3usize)?;
    let shards: usize = args.get_parse("shards", 2usize)?;
    let n_requests: usize = args.get_parse("requests", 512usize)?;
    let train_n: usize = args.get_parse("train", 128usize)?;
    let seed: u64 = args.get_parse("seed", 42u64)?;
    let fail_replica: i64 = args.get_parse("fail", -1i64)?;
    let policy = match args.get_or("policy", "least") {
        "rr" | "round-robin" => SchedulePolicy::RoundRobin,
        _ => SchedulePolicy::LeastOutstanding,
    };

    // Train on the host, then deploy the trained net fleet-wide — the
    // paper's train-once / serve-everywhere flow, scaled out.
    let mut net = Network::new(cfg.clone(), seed);
    if train_n > 0 {
        let d = synth::generate(cfg.img_side, cfg.n_classes, train_n, seed, 0.15);
        for img in &d.images {
            net.train_unsup_step(img);
        }
        for (img, &l) in d.images.iter().zip(&d.labels) {
            net.train_sup_step(img, l as usize);
        }
        println!("trained on {train_n} images (host)");
    }

    let server = ClusterServer::start_with(
        net,
        ClusterConfig {
            replicas,
            shards_per_replica: shards,
            queue_depth: 256,
            flush_timeout: Duration::from_millis(2),
            policy,
        },
    )?;
    let plan = server.plan();
    println!(
        "cluster up: {replicas} replicas x {shards} shards ({} devices), policy {policy:?}",
        replicas * shards
    );
    for s in &plan.shards {
        println!(
            "  shard {}: HCs [{}, {})  n_h {}  BRAM {:.1}  fmax {:.0} MHz  HBM {:.1} MB",
            s.id,
            s.hc_lo,
            s.hc_hi,
            s.n_units(),
            s.util.brams,
            s.util.freq_mhz,
            s.hbm_bytes as f64 / 1e6
        );
    }

    let data = synth::generate(cfg.img_side, cfg.n_classes, n_requests, seed + 1, 0.15);
    let mut pending = Vec::with_capacity(n_requests);
    let mut rejected = 0usize;
    for (i, img) in data.images.iter().enumerate() {
        if fail_replica >= 0 && i == n_requests / 2 {
            if server.fail_replica(fail_replica as usize) {
                println!("-- killing replica {fail_replica} mid-stream --");
            } else {
                println!("-- --fail {fail_replica} out of range (replicas 0..{replicas}) --");
            }
        }
        // Keep draining even if the cluster refuses new traffic (e.g.
        // the killed replica was the last healthy one): the report at
        // the end is the point of the demo.
        match server.submit(img.clone()) {
            Ok(rx) => pending.push((rx, data.labels[i])),
            Err(e) => {
                rejected += 1;
                if rejected == 1 {
                    println!("-- submissions rejected from request {i}: {e} --");
                }
            }
        }
    }

    let mut agree = 0usize;
    let mut lost = 0usize;
    for (rx, label) in &pending {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(probs) => {
                let pred = probs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if pred as u32 == *label {
                    agree += 1;
                }
            }
            Err(_) => lost += 1,
        }
    }
    println!("healthy replicas at drain: {}", server.healthy_replicas());

    let rep = server.shutdown();
    println!(
        "\nserved {} / {n_requests} requests  (re-routed {}, lost {lost}, rejected {rejected})",
        rep.served, rep.rerouted
    );
    println!(
        "cluster latency: mean {:.3} ms  p50 {:.3} ms  p99 {:.3} ms",
        rep.latency.mean_ms, rep.latency.p50_ms, rep.latency.p99_ms
    );
    for r in &rep.replicas {
        println!(
            "replica {}: served {:>5} in {:>4} batches (fill {:.1})  p99 {:.3} ms  {}{}",
            r.replica,
            r.served,
            r.batches,
            r.mean_fill,
            r.latency.p99_ms,
            if r.failed { "FAILED" } else { "ok" },
            if r.rerouted_out > 0 {
                format!(", re-routed {} out", r.rerouted_out)
            } else {
                String::new()
            }
        );
        for s in &r.shards {
            println!(
                "    shard {}: {} imgs  busy {:.1} ms  queue high-water {}",
                s.shard,
                s.items,
                s.busy.as_secs_f64() * 1e3,
                s.input_fifo.high_water
            );
        }
    }
    println!("label agreement: {agree}/{n_requests}");
    Ok(())
}
