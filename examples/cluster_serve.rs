//! Cluster serving demo: replicated *hybrid* BCPNN inference —
//! pipeline stages × hypercolumn shards — with scheduling and a
//! mid-stream device failure.
//!
//!     cargo run --release --example cluster_serve -- \
//!         --config mnist-deep2 --fleet u55c:3 --replicas 2 \
//!         --requests 256 --policy least --fail 1
//!
//! Trains briefly (host layer graph), deploys the trained graph to
//! every replica through the placement the hybrid planner picks for
//! the fleet (on `mnist-deep2` with 3 devices: the bottleneck layer
//! sharded 2-way, the other layer on its own stage), streams requests
//! through the cluster coordinator, kills one replica halfway, and
//! prints the per-replica / per-worker report: the scale-out path the
//! single-device `serve` command grows into.

use std::time::Duration;

use anyhow::Result;
use bcpnn_accel::bcpnn::LayerGraph;
use bcpnn_accel::cluster::{
    plan_hybrid, ClusterConfig, ClusterServer, Fleet, SchedulePolicy,
};
use bcpnn_accel::config::{by_name, FleetSpec};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::KernelVersion;
use bcpnn_accel::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let name = args.get_or("config", "mnist-deep2").to_string();
    let cfg = by_name(&name)?;
    let fleet_spec = FleetSpec::parse(args.get_or("fleet", "u55c:3"))?;
    let replicas: usize = args.get_parse("replicas", 2usize)?;
    let n_requests: usize = args.get_parse("requests", 256usize)?;
    let train_n: usize = args.get_parse("train", 96usize)?;
    let seed: u64 = args.get_parse("seed", 42u64)?;
    let fail_replica: i64 = args.get_parse("fail", -1i64)?;
    let tol: f64 = args.get_parse("tol", 0.10f64)?;
    let policy = match args.get_or("policy", "least") {
        "rr" | "round-robin" => SchedulePolicy::RoundRobin,
        _ => SchedulePolicy::LeastOutstanding,
    };

    // Train on the host, then deploy the trained graph fleet-wide —
    // the paper's train-once / serve-everywhere flow, scaled out.
    let mut graph = LayerGraph::new(cfg.clone(), seed);
    if train_n > 0 {
        let d = synth::generate(cfg.img_side, cfg.n_classes, train_n, seed, 0.15);
        for img in &d.images {
            graph.train_unsup_step(img);
        }
        for (img, &l) in d.images.iter().zip(&d.labels) {
            graph.train_sup_step(img, l as usize);
        }
        println!("trained on {train_n} images (host, {} hidden layers)", cfg.n_layers());
    }

    // One hybrid plan serves every replica: the planner picks the
    // stage cut and the shard fan-out from the modeled latencies.
    let fleet = Fleet::resolve(&fleet_spec)?;
    let plan = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, tol)?;
    println!(
        "cluster up: {replicas} replicas x {} devices (fleet [{}]), policy {policy:?}",
        plan.n_devices_used(),
        fleet_spec.devices.join(", ")
    );
    for st in &plan.stages {
        for p in &st.pieces {
            let dev = &plan.fleet[p.device_index];
            println!(
                "  stage {} layers {}..{} shard {}: HCs [{}, {}) on {}  fmax {:.0} MHz  \
                 kernel {:.1} us  HBM {:.1} MB",
                st.stage,
                st.layer_lo,
                st.layer_hi,
                p.shard,
                p.hc_lo,
                p.hc_hi,
                dev.name,
                p.util.freq_mhz,
                p.kernel_s * 1e6,
                p.hbm_bytes as f64 / 1e6
            );
        }
        println!(
            "  stage {} interval {:.1} us  skew {:.3}{}",
            st.stage,
            st.interval_s() * 1e6,
            st.skew(),
            if st.balanced { "" } else { "  [equal-split fallback]" }
        );
    }
    println!(
        "  modeled: bottleneck {:.1} us -> {:.0} img/s per replica",
        plan.bottleneck_s() * 1e6,
        plan.throughput_img_s()
    );

    let server = ClusterServer::start_hybrid(
        graph,
        &plan,
        ClusterConfig {
            replicas,
            // Ignored by start_hybrid — the per-replica topology comes
            // from the plan; the field only drives start_with.
            shards_per_replica: plan.n_devices_used(),
            queue_depth: 256,
            flush_timeout: Duration::from_millis(2),
            policy,
            ..ClusterConfig::default()
        },
    )?;

    let data = synth::generate(cfg.img_side, cfg.n_classes, n_requests, seed + 1, 0.15);
    let mut pending = Vec::with_capacity(n_requests);
    let mut rejected = 0usize;
    for (i, img) in data.images.iter().enumerate() {
        if fail_replica >= 0 && i == n_requests / 2 {
            if server.fail_replica(fail_replica as usize) {
                println!("-- killing replica {fail_replica} mid-stream --");
            } else {
                println!("-- --fail {fail_replica} out of range (replicas 0..{replicas}) --");
            }
        }
        // Keep draining even if the cluster refuses new traffic (e.g.
        // the killed replica was the last healthy one): the report at
        // the end is the point of the demo.
        match server.submit(img.clone()) {
            Ok(rx) => pending.push((rx, data.labels[i])),
            Err(e) => {
                rejected += 1;
                if rejected == 1 {
                    println!("-- submissions rejected from request {i}: {e} --");
                }
            }
        }
    }

    let mut agree = 0usize;
    let mut lost = 0usize;
    for (rx, label) in &pending {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(probs) => {
                let pred = probs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if pred as u32 == *label {
                    agree += 1;
                }
            }
            Err(_) => lost += 1,
        }
    }
    println!("healthy replicas at drain: {}", server.healthy_replicas());

    let rep = server.shutdown();
    println!(
        "\nserved {} / {n_requests} requests  (re-routed {}, lost {lost}, rejected {rejected})",
        rep.served, rep.rerouted
    );
    println!(
        "cluster latency: mean {:.3} ms  p50 {:.3} ms  p99 {:.3} ms  p999 {:.3} ms",
        rep.latency.mean_ms, rep.latency.p50_ms, rep.latency.p99_ms, rep.latency.p999_ms
    );
    for r in &rep.replicas {
        println!(
            "replica {}.{}: served {:>5} in {:>4} batches (fill {:.1})  p99 {:.3} ms  {}{}",
            r.replica,
            r.incarnation,
            r.served,
            r.batches,
            r.mean_fill,
            r.latency.p99_ms,
            if r.failed { "FAILED" } else { "ok" },
            if r.rerouted_out > 0 {
                format!(", re-routed {} out", r.rerouted_out)
            } else {
                String::new()
            }
        );
        for s in &r.shards {
            println!(
                "    stage {} shard {}: {} imgs  busy {:.1} ms  wait p99 {:.3} ms  \
                 svc p99 {:.3} ms  queue high-water {}",
                s.stage,
                s.shard,
                s.items,
                s.busy.as_secs_f64() * 1e3,
                s.queue_wait.p99_ms,
                s.service.p99_ms,
                s.input_fifo.high_water
            );
        }
    }
    println!("label agreement: {agree}/{n_requests}");
    Ok(())
}
