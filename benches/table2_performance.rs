//! Table 2 bench: regenerates the paper's performance comparison.
//!
//! Two kinds of rows:
//!  - **modeled** (paper shapes, models 1-3): the calibrated CPU/GPU/
//!    FPGA models — printed with the paper's values for comparison;
//!  - **measured** (reduced shapes): real timings on this host for the
//!    pure-rust CPU baseline and the PJRT artifact path.
//!
//!     cargo bench --bench table2_performance

use std::path::Path;

use bcpnn_accel::baseline::cpu;
use bcpnn_accel::bcpnn::Network;
use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::Driver;
use bcpnn_accel::data::synth;
use bcpnn_accel::report;
use bcpnn_accel::runtime::Session;

fn main() {
    // Part 1: modeled Table 2 at paper shapes.
    println!("{}", report::table2(&["model1", "model2", "model3"]).unwrap());
    println!("{}", report::table2_totals(&["model1", "model2", "model3"]).unwrap());

    // Part 2: measured rows at reduced shapes on this host.
    println!("measured on this host (single core):");
    println!("{}", bh::header());
    for name in ["tiny", "small", "edge"] {
        let cfg = by_name(name).unwrap();
        let d = synth::generate(cfg.img_side, cfg.n_classes, 256, 3, 0.15);

        // CPU baseline: pure-rust sequential network.
        let net = Network::new(cfg.clone(), 1);
        let images = d.images.clone();
        let r = bh::bench(&format!("{name}/cpu-rust/infer (256 img)"), 1, 5, || {
            std::hint::black_box(cpu::measure_infer_ms(&net, &images));
        });
        println!("{}", r.row());
        let mut net2 = Network::new(cfg.clone(), 1);
        let images2 = d.images.clone();
        let r = bh::bench(&format!("{name}/cpu-rust/train (256 img)"), 1, 3, || {
            std::hint::black_box(cpu::measure_train_ms(&mut net2, &images2));
        });
        println!("{}", r.row());

        // PJRT path (the accelerator stand-in): batched infer + train.
        if Path::new("artifacts/manifest.json").exists() {
            if let Ok(session) = Session::load(Path::new("artifacts"), name) {
                let mut driver = Driver::new(session, name, 1).unwrap();
                let batch: Vec<Vec<f32>> = d.images[..cfg.batch].to_vec();
                let r = bh::bench(
                    &format!("{name}/pjrt/infer_batch ({} img)", cfg.batch),
                    2,
                    10,
                    || {
                        std::hint::black_box(driver.infer_batch(&batch).unwrap());
                    },
                );
                println!("{}  ({:.3} ms/img)", r.row(),
                         r.mean.as_secs_f64() * 1e3 / cfg.batch as f64);
                let batch2 = batch.clone();
                let r = bh::bench(
                    &format!("{name}/pjrt/unsup_batch ({} img)", cfg.batch),
                    1,
                    5,
                    || {
                        driver.unsup_batch(&batch2).unwrap();
                    },
                );
                println!("{}  ({:.3} ms/img)", r.row(),
                         r.mean.as_secs_f64() * 1e3 / cfg.batch as f64);
            }
        } else {
            println!("(artifacts missing — PJRT rows skipped; run `make artifacts`)");
        }
    }
}
