//! Stream-runtime microbenchmarks: FIFO ops/sec, pipeline dispatch
//! overhead, and depth-analysis cost — the L3 hot-path numbers the
//! §Perf pass tracks.
//!
//!     cargo bench --bench stream_runtime            # stdout tables
//!     cargo bench --bench stream_runtime -- --json  # + BENCH_stream_runtime.json

use std::path::Path;

use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::stream::depth::{minimal_depths, simulate, StageSpec};
use bcpnn_accel::stream::{Fifo, Pipeline};
use bcpnn_accel::util::json::Json;

fn main() {
    let opts = bh::BenchOpts::from_args();
    let mut results: Vec<bh::BenchResult> = Vec::new();

    println!("== stream runtime microbenches ==");
    println!("{}", bh::header());

    // FIFO send/recv round trip, single thread (pure channel cost;
    // interleaved so the bounded FIFO never fills).
    let f = Fifo::with_capacity(64);
    let r = bh::bench("fifo send+recv same-thread (1k items)", 3, 20, || {
        for i in 0..1000u64 {
            f.send(i).unwrap();
            f.recv().unwrap();
        }
    });
    println!("{}  ({:.0} Mops/s)", r.row(), 2000.0 / r.mean.as_secs_f64() / 1e6);
    results.push(r);

    // Cross-thread streaming throughput.
    let r = bh::bench("fifo producer->consumer (10k items)", 1, 10, || {
        let f: Fifo<u64> = Fifo::with_capacity(256);
        let tx = f.clone();
        let h = std::thread::spawn(move || {
            for i in 0..10_000u64 {
                tx.send(i).unwrap();
            }
            tx.close();
        });
        let mut sum = 0u64;
        while let Ok(v) = f.recv() {
            sum = sum.wrapping_add(v);
        }
        std::hint::black_box(sum);
        h.join().unwrap();
    });
    println!("{}  ({:.2} Mitems/s)", r.row(), 10_000.0 / r.mean.as_secs_f64() / 1e6);
    results.push(r);

    // Pipeline dispatch overhead: empty stages.
    for n_stages in [1usize, 2, 4] {
        let r = bh::bench(&format!("pipeline {} no-op stages (5k items)", n_stages), 1, 5, || {
            let mut p = Pipeline::source("src", 64, 0..5000u64);
            for i in 0..n_stages {
                p = p.stage(&format!("s{i}"), 64, |x: u64| x);
            }
            let (out, _) = p.collect();
            std::hint::black_box(out.len());
        });
        println!("{}  ({:.0} ns/item/stage)", r.row(),
                 r.mean.as_nanos() as f64 / 5000.0 / n_stages as f64);
        results.push(r);
    }

    // Depth analysis cost (the build-time cosim analogue).
    let stages = vec![
        StageSpec::streaming("read", 1),
        StageSpec::with_barrier("softmax", 2, 8),
        StageSpec::streaming("write", 1),
    ];
    let r = bh::bench("depth simulate (3 stages, 4k items)", 1, 10, || {
        std::hint::black_box(simulate(&stages, &[8, 8], 4096));
    });
    println!("{}", r.row());
    results.push(r);
    let r = bh::bench("minimal_depths search (3 stages)", 1, 5, || {
        std::hint::black_box(minimal_depths(&stages, 1024, 0.05));
    });
    println!("{}", r.row());
    results.push(r);

    if opts.json {
        let report = Json::obj(vec![
            ("bench", Json::from("stream_runtime")),
            ("source", Json::from("measured")),
            ("cases", Json::Arr(results.iter().map(bh::BenchResult::to_json).collect())),
        ]);
        let path =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_stream_runtime.json");
        bh::write_json_report(&path, &report).expect("write BENCH_stream_runtime.json");
        println!("wrote {}", path.display());
    }
}
