//! Cluster scaling bench: throughput at 1/2/4/8 shards.
//!
//!     cargo bench --bench cluster_scaling
//!
//! Two kinds of rows (same convention as the other benches):
//!
//!  1. **cycle-modeled** (the FPGA claim's currency): each shard's
//!     sub-config goes through the calibrated `fpga::estimator` +
//!     `fpga::timing` device model; cluster throughput is set by the
//!     slowest shard's bottleneck stage, exactly like the single-device
//!     dataflow analysis. Splitting the hidden layer shrinks the
//!     support/HBM streams per device *and* relaxes BRAM routing
//!     pressure (higher fmax), so scaling is super-linear on
//!     BRAM-pressured models. This section is deterministic.
//!  2. **measured**: wall-clock throughput of the software
//!     `ShardedExecutor` on this host (informational on low-core
//!     machines — shard workers are real threads and need cores to
//!     overlap, exactly like `ablation_dataflow`).

use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::bcpnn::{LayerGraph, Network};
use bcpnn_accel::cluster::{
    plan, plan_hybrid, plan_pipeline, Fleet, HybridExecutor, PipelineParallelExecutor,
    ShardedExecutor,
};
use bcpnn_accel::config::{by_name, ModelConfig};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::fpga::timing;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn modeled_section(model: &str) {
    let cfg = by_name(model).unwrap();
    let dev = FpgaDevice::u55c();
    println!("\n-- {model}: cycle-modeled scaling (infer build) --");
    println!(
        "{:<8} {:>10} {:>9} {:>12} {:>14} {:>9}",
        "shards", "max n_h", "fmax MHz", "kernel us", "img/s (kern)", "speedup"
    );
    let mut base_tp = 0.0f64;
    let mut speedup_at = [0.0f64; SHARD_COUNTS.len()];
    for (si, &n) in SHARD_COUNTS.iter().enumerate() {
        let p = plan(&cfg, n, KernelVersion::Infer, &dev).unwrap();
        // Steady state: every device pipelines images; the slowest
        // shard's bottleneck stage sets the cluster's per-image rate.
        let worst = p
            .shards
            .iter()
            .map(|s| timing::breakdown(&s.sub_cfg, KernelVersion::Infer, &dev))
            .max_by(|a, b| a.kernel_s().partial_cmp(&b.kernel_s()).unwrap())
            .unwrap();
        let tp = 1.0 / worst.kernel_s();
        if si == 0 {
            base_tp = tp;
        }
        speedup_at[si] = tp / base_tp;
        let max_nh = p.shards.iter().map(|s| s.sub_cfg.n_h()).max().unwrap();
        println!(
            "{:<8} {:>10} {:>9.1} {:>12.2} {:>14.0} {:>8.2}x",
            n,
            max_nh,
            worst.freq_hz / 1e6,
            worst.kernel_s() * 1e6,
            tp,
            speedup_at[si]
        );
    }
    let s4 = speedup_at[SHARD_COUNTS.iter().position(|&n| n == 4).unwrap()];
    println!(
        "4-shard speedup vs 1 shard: {s4:.2}x  (>= 2x target: {})",
        if s4 >= 2.0 { "PASS" } else { "FAIL" }
    );
}

/// A serving-sized config for the measured section: big enough hidden
/// layer that per-shard support work dominates queue overhead.
fn measured_cfg() -> ModelConfig {
    let mut cfg = by_name("small").unwrap();
    cfg.name = "cluster-bench".into();
    cfg.hc_h = 8;
    cfg.mc_h = 128; // n_h = 1024
    cfg.nact_hi = 96;
    cfg.batch = 32;
    cfg.validate().unwrap();
    cfg
}

fn measured_section(ms_per_case: u64) {
    let cfg = measured_cfg();
    let dev = FpgaDevice::u55c();
    let net = Network::new(cfg.clone(), 42);
    let data = synth::generate(cfg.img_side, cfg.n_classes, 64, 7, 0.15);
    println!(
        "\n-- measured: ShardedExecutor wall-clock ({}; {} imgs/iter; host-core bound) --",
        cfg.name,
        data.len()
    );
    println!("{}", bh::header());
    let mut base = 0.0f64;
    for &n in &[1usize, 2, 4] {
        let p = plan(&cfg, n, KernelVersion::Infer, &dev).unwrap();
        let exec = ShardedExecutor::new(net.clone(), &p).unwrap();
        let r = bh::bench_for(
            &format!("infer_batch x{} imgs, {} shard(s)", data.len(), n),
            std::time::Duration::from_millis(ms_per_case),
            || {
                let out = exec.infer_batch(&data.images).unwrap();
                std::hint::black_box(out.len());
            },
        );
        let tp = r.throughput(data.len() as u64);
        if n == 1 {
            base = tp;
        }
        println!("{}  ({:.0} img/s, {:.2}x)", r.row(), tp, tp / base);
        drop(exec);
    }
}

/// Deep-stack section: pipeline-parallel scaling of a stacked config.
/// Cycle-modeled (deterministic): per-layer kernel times from the
/// device model; pipeline throughput = 1 / slowest layer vs the
/// single-device chain paying the *sum* of layers per image. Measured:
/// the software `PipelineParallelExecutor` vs the sequential reference.
fn deep_stack_section(ms_per_case: u64) {
    let dev = FpgaDevice::u55c();
    println!("\n-- deep stack: pipeline-parallel layer placement --");
    for model in ["mnist-deep2", "toy-deep"] {
        let cfg = by_name(model).unwrap();
        let p = plan_pipeline(&cfg, KernelVersion::Infer, &dev).unwrap();
        println!("{model}: {} layers, cycle-modeled (infer build)", p.n_devices());
        println!(
            "{:<8} {:>12} {:>9} {:>12} {:>10}",
            "layer", "out(HCxMC)", "fmax MHz", "kernel us", "HBM MB"
        );
        for s in &p.stages {
            println!(
                "{:<8} {:>6}x{:<5} {:>9.1} {:>12.2} {:>10.1}",
                s.device,
                s.dims.hc_out,
                s.dims.mc_out,
                s.util.freq_mhz,
                s.kernel_s * 1e6,
                s.hbm_bytes as f64 / 1e6,
            );
        }
        let chained = p.latency_s();
        let bottleneck = p.bottleneck().kernel_s;
        println!(
            "single device {:.2} us/img, pipeline {:.2} us/img ({:.2}x, bottleneck layer {})",
            chained * 1e6,
            bottleneck * 1e6,
            chained / bottleneck,
            p.bottleneck().device,
        );
    }

    // Measured: software executor wall-clock on the toy stack.
    let cfg = by_name("toy-deep").unwrap();
    let graph = LayerGraph::new(cfg.clone(), 42);
    let data = synth::generate(cfg.img_side, cfg.n_classes, 64, 7, 0.15);
    let pplan = plan_pipeline(&cfg, KernelVersion::Infer, &dev).unwrap();
    println!("\n{}", bh::header());
    let seq_graph = graph.clone();
    let r_seq = bh::bench_for(
        &format!("LayerGraph::infer x{} imgs (sequential)", data.len()),
        std::time::Duration::from_millis(ms_per_case),
        || {
            for img in &data.images {
                std::hint::black_box(seq_graph.infer(img).len());
            }
        },
    );
    println!("{}  ({:.0} img/s)", r_seq.row(), r_seq.throughput(data.len() as u64));
    let exec = PipelineParallelExecutor::new(graph, &pplan).unwrap();
    let r_pipe = bh::bench_for(
        &format!("PipelineParallelExecutor x{} imgs", data.len()),
        std::time::Duration::from_millis(ms_per_case),
        || {
            let out = exec.infer_batch(&data.images).unwrap();
            std::hint::black_box(out.len());
        },
    );
    println!(
        "{}  ({:.0} img/s; host-core bound)",
        r_pipe.row(),
        r_pipe.throughput(data.len() as u64)
    );
}

/// Hybrid section: the unified planner against both degenerate
/// strategies on `mnist-deep2`. Cycle-modeled (deterministic, runs in
/// `--quick` too) and **asserted**: the hybrid plan's modeled
/// throughput must be at least the best of pure-pipeline and
/// pure-shard — CI runs this as the bench-smoke gate. A measured
/// wall-clock row for the software `HybridExecutor` rides along.
fn hybrid_section(ms_per_case: u64) {
    let dev = FpgaDevice::u55c();
    let cfg = by_name("mnist-deep2").unwrap();
    println!("\n-- hybrid: pipeline stages x hypercolumn shards (mnist-deep2, 3 devices) --");

    let fleet = Fleet::homogeneous(&dev, 3);
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1).unwrap();
    for st in &hp.stages {
        for p in &st.pieces {
            println!(
                "stage {} layers {}..{} shard {}: HCs [{:>2},{:>2})  fmax {:>5.1} MHz  kernel {:>8.2} us",
                st.stage, st.layer_lo, st.layer_hi, p.shard, p.hc_lo, p.hc_hi,
                p.util.freq_mhz, p.kernel_s * 1e6,
            );
        }
    }
    let hybrid_tp = hp.throughput_img_s();

    let pipe = plan_pipeline(&cfg, KernelVersion::Infer, &dev).unwrap();
    let pipe_tp = pipe.throughput_img_s();
    // Pure hypercolumn sharding cannot express a stacked config at
    // all — its throughput contribution to "best of" is zero.
    let shard_tp = match plan(&cfg, 3, KernelVersion::Infer, &dev) {
        Ok(p) => {
            let worst = p
                .shards
                .iter()
                .map(|s| timing::breakdown(&s.sub_cfg, KernelVersion::Infer, &dev).kernel_s())
                .fold(0.0f64, f64::max);
            1.0 / worst.max(1e-15)
        }
        Err(_) => 0.0,
    };
    let best_pure = pipe_tp.max(shard_tp);
    println!(
        "modeled img/s: hybrid {:.0}  pure-pipeline {:.0}  pure-shard {}",
        hybrid_tp,
        pipe_tp,
        if shard_tp > 0.0 { format!("{shard_tp:.0}") } else { "illegal (stacked)".into() },
    );
    println!(
        "hybrid >= best pure strategy: {}  ({:.2}x)",
        if hybrid_tp >= best_pure { "PASS" } else { "FAIL" },
        hybrid_tp / best_pure.max(1e-15),
    );
    assert!(
        hybrid_tp >= best_pure,
        "hybrid plan must subsume both pure strategies: {hybrid_tp} vs {best_pure}"
    );

    // Measured: software hybrid executor on the toy stack (3 devices:
    // one layer sharded, one solo — both fan-out and chaining live).
    let cfg = by_name("toy-deep").unwrap();
    let graph = LayerGraph::new(cfg.clone(), 42);
    let data = synth::generate(cfg.img_side, cfg.n_classes, 64, 7, 0.15);
    let hp = plan_hybrid(
        &cfg,
        &Fleet::homogeneous(&dev, 3),
        KernelVersion::Infer,
        0.1,
    )
    .unwrap();
    let exec = HybridExecutor::new(graph, &hp).unwrap();
    println!("\n{}", bh::header());
    let r = bh::bench_for(
        &format!("HybridExecutor x{} imgs (toy-deep, 3 devices)", data.len()),
        std::time::Duration::from_millis(ms_per_case),
        || {
            let out = exec.infer_batch(&data.images).unwrap();
            std::hint::black_box(out.len());
        },
    );
    println!(
        "{}  ({:.0} img/s; host-core bound)",
        r.row(),
        r.throughput(data.len() as u64)
    );
}

fn main() {
    // `--quick` (the CI bench-smoke mode) trims the wall-clock
    // sections; the cycle-modeled sections — including the asserted
    // hybrid-vs-pure comparison — are deterministic and run in full
    // either way.
    let quick = std::env::args().any(|a| a == "--quick");
    let ms_per_case = if quick { 40 } else { 300 };
    println!("== cluster scaling: shard the hidden layer across devices ==");
    for model in ["model1", "model2"] {
        modeled_section(model);
    }
    measured_section(ms_per_case);
    deep_stack_section(ms_per_case);
    hybrid_section(ms_per_case);
}
