//! Autotuner bench: wall-clock cost of the deployment search and the
//! "never worse than a pure strategy" invariant, per config.
//!
//!     cargo bench --bench tune
//!     cargo bench --bench tune -- --quick --json   # + BENCH_tune.json
//!
//! Each row runs the full `tune::tune` search (FPGA replica slices x
//! plan_hybrid x precision, plus the host tile family) with no
//! workload constraints and reports: search wall time, candidates
//! costed vs pruned, the winner's modeled operating point, and the
//! winner-vs-baseline throughput ratios. The invariant asserted here
//! is the same one `rust/tests/tune.rs` gates CI on: the winner's
//! modeled throughput is >= every feasible pure strategy.
//!
//! `--json` writes `BENCH_tune.json` at the repo root (the committed
//! copy is a modeled-seed snapshot: the numbers are deterministic
//! model evaluations, so they don't drift with host load).

use std::path::Path;

use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::config::by_name;
use bcpnn_accel::tune::{tune, TuneOptions, Workload};
use bcpnn_accel::util::json::Json;

fn main() {
    let opts = bh::BenchOpts::from_args();
    let names: &[&str] = if opts.quick {
        &["tiny", "mnist-deep2"]
    } else {
        &["tiny", "model1", "model2", "mnist-deep2", "toy-deep"]
    };
    let (warmup, iters) = if opts.quick { (1, 3) } else { (2, 5) };

    println!("== deployment autotuner: search cost + invariant ==");
    println!("{}", bh::header());

    let mut entries: Vec<Json> = Vec::new();
    for &name in names {
        let cfg = by_name(name).unwrap();
        let topts = TuneOptions::default();
        let w = Workload::default();
        let r = bh::bench(&format!("tune {name} (u55c:3, host+fpga)"), warmup, iters, || {
            std::hint::black_box(tune(&cfg, &w, &topts).unwrap().evaluated);
        });
        println!("{}", r.row());

        let out = tune(&cfg, &w, &topts).unwrap();
        let tp = out.spec.modeled.throughput_img_s;
        for b in &out.baselines {
            if let Some(base) = b.throughput_img_s {
                assert!(
                    tp >= base * (1.0 - 1e-9),
                    "{name}: tuner {tp:.0} img/s below {} {base:.0} img/s",
                    b.name
                );
            }
        }
        let searched = out.evaluated + out.pruned;
        println!(
            "  winner: {} {:.0} img/s, {:.1} W  ({} costed / {} searched, {} feasible)",
            out.spec.backend.name(),
            tp,
            out.spec.modeled.power_w,
            out.evaluated,
            searched,
            out.feasible,
        );
        let baselines = Json::obj(
            out.baselines
                .iter()
                .map(|b| (b.name, b.throughput_img_s.map(Json::from).unwrap_or(Json::Null)))
                .collect(),
        );
        entries.push(Json::obj(vec![
            ("config", Json::from(name)),
            ("search", r.to_json()),
            ("evaluated", Json::from(out.evaluated)),
            ("pruned", Json::from(out.pruned)),
            ("feasible", Json::from(out.feasible)),
            ("winner", out.spec.to_json()),
            ("baselines", baselines),
        ]));
    }

    if opts.json {
        let report = Json::obj(vec![
            ("bench", Json::from("tune")),
            ("source", Json::from("measured")),
            ("fleet", Json::from("u55c:3")),
            ("configs", Json::Arr(entries)),
        ]);
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_tune.json");
        bh::write_json_report(&path, &report).expect("write BENCH_tune.json");
        println!("\nwrote {}", path.display());
    }
}
