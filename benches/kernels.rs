//! Active-synapse kernel bench: the dense seed kernels vs the
//! block-sparse engine vs the batched AoSoA tile engine, ns/img per
//! registry config — the measured side of the `hc_in/nact` speedup the
//! machine model predicts (`fpga::timing::active_synapses` streams
//! `nact * mc_in * n_out` terms; the dense host loop touched all
//! `n_in * n_out`) and of the tile amortization
//! (`fpga::timing::host_tile_img_s` models one weight load per TILE
//! lanes).
//!
//!     cargo bench --bench kernels                 # full registry
//!     cargo bench --bench kernels -- --quick      # CI smoke subset
//!     cargo bench --bench kernels -- --json       # + BENCH_kernels.json
//!     cargo bench --bench kernels -- --threads 4  # threaded tile row
//!
//! In every mode the bench **asserts**, on `mnist-deep2`:
//! - block-sparse support is at least 2x faster than dense (front
//!   layer = model1-class dims, modeled `hc_in/nact = 784/128 ≈ 6x`);
//! - batched tile inference throughput ≥ the single-image span loop
//!   (modeled ~6x from weight-stream amortization);
//! - batched-EMA training throughput ≥ the sequential per-image
//!   trainer (the fold recomputes the div+ln weight map once per span
//!   per tile instead of once per image);
//! - int8 dequant-in-register tile inference ≥ the f32-store tile row
//!   (1/4 the weight bytes per span walk), and the modeled
//!   single-stream roofline keeps int8 at ≥ 2x f32 images/s —
//! so none of the engines can silently regress in CI.

use std::hint::black_box;
use std::path::Path;

use bcpnn_accel::bcpnn::sparse::{dense_support_masked, dense_train_step, TILE};
use bcpnn_accel::bcpnn::{LayerGraph, QuantFormat, Workspace};
use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::config::{by_name, registry};
use bcpnn_accel::data::encode::encode_image;
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::timing::{host_tile_img_s, host_tile_img_s_bytes};
use bcpnn_accel::util::json::Json;

fn ns_per_img(r: &bh::BenchResult, imgs: usize) -> f64 {
    r.mean.as_nanos() as f64 / imgs.max(1) as f64
}

fn main() {
    let opts = bh::BenchOpts::from_args();
    let names: Vec<String> = if opts.quick {
        ["tiny", "toy-deep", "mnist-deep2"].map(String::from).to_vec()
    } else {
        registry().keys().cloned().collect()
    };
    let (n_imgs, warmup, iters) = if opts.quick { (2usize, 1u32, 3u32) } else { (4, 1, 5) };

    println!("== active-synapse kernels: dense seed vs block-sparse ==");
    println!("{}", bh::header());

    let mut entries: Vec<Json> = Vec::new();
    for name in &names {
        let cfg = by_name(name).unwrap();
        let g = LayerGraph::new(cfg.clone(), 42);
        let d = synth::generate(cfg.img_side, cfg.n_classes, n_imgs, 7, 0.15);
        let xs: Vec<Vec<f32>> = d.images.iter().map(|i| encode_image(i)).collect();
        let l0 = &g.layers[0];
        let dims = l0.dims;
        let mask = l0.dense_mask();

        // Support mat-vec, layer 0: the inner loop everything runs on.
        let r_dense = bh::bench(&format!("{name} support dense"), warmup, iters, || {
            for x in &xs {
                black_box(dense_support_masked(&l0.bj, &l0.wij, &mask, x));
            }
        });
        println!("{}", r_dense.row());
        let mut buf: Vec<f32> = Vec::new();
        let r_sparse = bh::bench(&format!("{name} support block-sparse"), warmup, iters, || {
            for x in &xs {
                l0.support_masked_into(x, &mut buf);
                black_box(buf.last().copied());
            }
        });
        println!("{}", r_sparse.row());
        let speedup = ns_per_img(&r_dense, n_imgs) / ns_per_img(&r_sparse, n_imgs).max(1.0);

        // One fused plasticity step (traces dense, weight map sparse).
        let y0 = l0.activate_masked(&xs[0], cfg.gain);
        let (mut pi, mut pj, mut pij, mut wij, mut bj) = (
            l0.pi.clone(), l0.pj.clone(), l0.pij.clone(), l0.wij.clone(), l0.bj.clone(),
        );
        let r_tdense = bh::bench(&format!("{name} train dense"), warmup, iters, || {
            dense_train_step(
                &mut pi, &mut pj, &mut pij, &mut wij, &mut bj,
                &xs[0], &y0, cfg.alpha, cfg.eps,
            );
        });
        println!("{}", r_tdense.row());
        let mut sp = l0.clone();
        let r_tsparse = bh::bench(&format!("{name} train block-sparse"), warmup, iters, || {
            sp.train_step(&xs[0], &y0, cfg.alpha, cfg.eps);
        });
        println!("{}", r_tsparse.row());
        let train_speedup =
            r_tdense.mean.as_secs_f64() / r_tsparse.mean.as_secs_f64().max(1e-12);

        // End-to-end inference through the zero-alloc workspace path.
        let mut ws = Workspace::new();
        let r_infer = bh::bench(&format!("{name} infer (workspace)"), warmup, iters, || {
            for img in &d.images {
                black_box(g.infer_with(img, &mut ws).last().copied());
            }
        });
        println!("{}", r_infer.row());

        // Batched section: single-image span loop vs the AoSoA tile
        // engine vs tile + thread splitter, on a batch with a ragged
        // tail (so the pad-lane path is always measured too).
        let n_batch = if opts.quick { 2 * TILE + 3 } else { 4 * TILE + 3 };
        let db = synth::generate(cfg.img_side, cfg.n_classes, n_batch, 11, 0.15);
        let mut bws = Workspace::new();
        // Every row black-boxes a computed float (not just a length
        // derivable from the input count), so the optimizer cannot
        // elide the inference work either side of the CI gate.
        let probe = |out: &[Vec<f32>]| out.last().and_then(|v| v.last().copied());
        let r_bsingle =
            bh::bench(&format!("{name} batch single-image span"), warmup, iters, || {
                let out: Vec<Vec<f32>> = db
                    .images
                    .iter()
                    .map(|i| g.infer_with(i, &mut bws).to_vec())
                    .collect();
                black_box(probe(&out));
            });
        println!("{}", r_bsingle.row());
        // Hoist the tile workspace like the single-image row hoists
        // `bws`, so the rows compare kernel throughput, not the
        // allocation asymmetry of a per-iteration fresh workspace.
        let mut tws = Workspace::new();
        let r_btile = bh::bench(&format!("{name} batch AoSoA tile"), warmup, iters, || {
            black_box(probe(&g.infer_batch_with(&db.images, &mut tws)));
        });
        println!("{}", r_btile.row());
        let thr = opts.threads.max(1);
        let r_bthr = bh::bench(
            &format!("{name} batch tile x{thr} threads"),
            warmup,
            iters,
            || {
                black_box(probe(&g.infer_batch_threads(&db.images, thr)));
            },
        )
        .with_threads(thr);
        println!("{}", r_bthr.row());
        let tile_speedup = ns_per_img(&r_bsingle, n_batch) / ns_per_img(&r_btile, n_batch).max(1.0);
        let tile_thr_speedup =
            ns_per_img(&r_bsingle, n_batch) / ns_per_img(&r_bthr, n_batch).max(1.0);

        // Quantized weight-store rows: the dequant-in-register tile
        // engine per narrow format vs the f32 tile row above — one
        // narrow weight load per span walk instead of one f32 load.
        let mut gq_bf16 = g.clone();
        gq_bf16.set_precision(QuantFormat::Bf16);
        let mut qws_bf16 = Workspace::new();
        let r_qbf16 = bh::bench(&format!("{name} batch tile bf16 store"), warmup, iters, || {
            black_box(probe(&gq_bf16.infer_batch_with(&db.images, &mut qws_bf16)));
        });
        println!("{}", r_qbf16.row());
        let mut gq_int8 = g.clone();
        gq_int8.set_precision(QuantFormat::Int8);
        let mut qws_int8 = Workspace::new();
        let r_qint8 = bh::bench(&format!("{name} batch tile int8 store"), warmup, iters, || {
            black_box(probe(&gq_int8.infer_batch_with(&db.images, &mut qws_int8)));
        });
        println!("{}", r_qint8.row());
        let bf16_tile_speedup =
            ns_per_img(&r_btile, n_batch) / ns_per_img(&r_qbf16, n_batch).max(1.0);
        let int8_tile_speedup =
            ns_per_img(&r_btile, n_batch) / ns_per_img(&r_qint8, n_batch).max(1.0);
        // Modeled roofline shift in the single-stream regime (tile=1:
        // one weight word per MAC streams from memory, so the narrow
        // store moves the bandwidth wall by bytes-per-weight).
        let modeled_stream = |fmt: QuantFormat| {
            host_tile_img_s_bytes(&cfg, 1, 1, fmt.bytes_per_weight())
                / host_tile_img_s_bytes(&cfg, 1, 1, QuantFormat::F32.bytes_per_weight())
        };

        // Training: sequential per-image EMA steps vs the batched-EMA
        // tile fold vs the fold + data-parallel shard merge. Each row
        // owns a clone and evolves its traces across iterations
        // (training mutates state), so all rows time the same work
        // from the same start.
        let mut tg_seq = g.clone();
        let r_tseq = bh::bench(&format!("{name} train seq per-image"), warmup, iters, || {
            for img in &db.images {
                tg_seq.train_unsup_step(img);
            }
            black_box(tg_seq.layers[0].pi[0]);
        });
        println!("{}", r_tseq.row());
        let mut tg_bat = g.clone();
        let r_tbat = bh::bench(&format!("{name} train batched-EMA tile"), warmup, iters, || {
            tg_bat.train_batch(&db.images);
            black_box(tg_bat.layers[0].pi[0]);
        });
        println!("{}", r_tbat.row());
        let mut tg_thr = g.clone();
        let r_tthr = bh::bench(
            &format!("{name} train batched x{thr} threads"),
            warmup,
            iters,
            || {
                tg_thr.train_batch_threads(&db.images, thr);
                black_box(tg_thr.layers[0].pi[0]);
            },
        )
        .with_threads(thr);
        println!("{}", r_tthr.row());
        let train_tile_speedup =
            ns_per_img(&r_tseq, n_batch) / ns_per_img(&r_tbat, n_batch).max(1.0);
        let train_thr_speedup =
            ns_per_img(&r_tseq, n_batch) / ns_per_img(&r_tthr, n_batch).max(1.0);

        println!(
            "   -> layer0 {}x{} HC (nact {}): support speedup {speedup:.2}x \
             (modeled ~{:.1}x), train speedup {train_speedup:.2}x",
            dims.hc_in, dims.hc_out, dims.nact,
            dims.hc_in as f64 / dims.nact as f64,
        );
        println!(
            "   -> batch tile speedup {tile_speedup:.2}x (modeled ~{:.1}x), \
             tile x{thr} threads {tile_thr_speedup:.2}x",
            host_tile_img_s(&cfg, TILE, 1) / host_tile_img_s(&cfg, 1, 1),
        );
        println!(
            "   -> train batched-EMA speedup {train_tile_speedup:.2}x, \
             batched x{thr} threads {train_thr_speedup:.2}x",
        );
        println!(
            "   -> tile store: bf16 {bf16_tile_speedup:.2}x, int8 {int8_tile_speedup:.2}x \
             vs f32 (modeled stream {:.1}x / {:.1}x at tile=1)",
            modeled_stream(QuantFormat::Bf16),
            modeled_stream(QuantFormat::Int8),
        );

        if name.as_str() == "mnist-deep2" {
            // Acceptance gate: modeled speedup is ~6.1x here; demand
            // the >=2x floor so a real regression can't hide behind
            // runner noise while a noisy-but-healthy run still passes.
            assert!(
                speedup >= 2.0,
                "block-sparse support only {speedup:.2}x vs dense on mnist-deep2 \
                 ({:.0} vs {:.0} ns/img) — below the 2x acceptance floor \
                 (modeled ~6.1x); active-synapse engine regressed",
                ns_per_img(&r_sparse, n_imgs),
                ns_per_img(&r_dense, n_imgs),
            );
            // Acceptance gate: the tile engine must not fall behind
            // the single-image span loop (modeled ~6x ahead via
            // weight-stream amortization; >=1x floors out noise).
            assert!(
                tile_speedup >= 1.0,
                "batched tile inference only {tile_speedup:.2}x vs single-image span \
                 on mnist-deep2 ({:.0} vs {:.0} ns/img) — tile engine regressed \
                 below the single-image throughput floor (modeled ~6x ahead)",
                ns_per_img(&r_btile, n_batch),
                ns_per_img(&r_bsingle, n_batch),
            );
            // Acceptance gate: the batched-EMA trainer folds TILE EMA
            // steps into one span walk and recomputes the div+ln
            // weight map once per span instead of once per image, so
            // it must never fall behind the sequential trainer.
            assert!(
                train_tile_speedup >= 1.0,
                "batched-EMA training only {train_tile_speedup:.2}x vs sequential \
                 per-image steps on mnist-deep2 ({:.0} vs {:.0} ns/img) — tile \
                 trainer regressed below the sequential throughput floor \
                 (weight-map amortization is ~TILEx per span)",
                ns_per_img(&r_tbat, n_batch),
                ns_per_img(&r_tseq, n_batch),
            );
            // Acceptance gate: int8 streams 1/4 the weight bytes per
            // span walk, so on this memory-bound model the dequant
            // tile engine must not fall behind the f32 store.
            assert!(
                int8_tile_speedup >= 1.0,
                "int8 tile inference only {int8_tile_speedup:.2}x vs the f32 store \
                 on mnist-deep2 ({:.0} vs {:.0} ns/img) — dequant-in-register \
                 engine regressed below the f32 throughput floor \
                 (modeled 4x up the bandwidth roof at tile=1)",
                ns_per_img(&r_qint8, n_batch),
                ns_per_img(&r_btile, n_batch),
            );
            // Acceptance gate: the modeled single-stream roofline must
            // keep int8 at >= 2x f32 images/s (it is exactly 4x while
            // tile=1 stays bandwidth-bound).
            let m_int8 = modeled_stream(QuantFormat::Int8);
            assert!(
                m_int8 >= 2.0,
                "modeled int8 single-stream throughput only {m_int8:.2}x f32 on \
                 mnist-deep2 — the bytes-per-weight roofline regressed"
            );
        }

        entries.push(Json::obj(vec![
            ("config", Json::from(name.as_str())),
            ("hc_in", Json::from(dims.hc_in)),
            ("nact", Json::from(dims.nact)),
            ("modeled_speedup", Json::from(dims.hc_in as f64 / dims.nact as f64)),
            ("support_dense_ns_per_img", Json::from(ns_per_img(&r_dense, n_imgs))),
            ("support_sparse_ns_per_img", Json::from(ns_per_img(&r_sparse, n_imgs))),
            ("support_speedup", Json::from(speedup)),
            ("train_dense_ns", Json::from(r_tdense.mean.as_nanos() as f64)),
            ("train_sparse_ns", Json::from(r_tsparse.mean.as_nanos() as f64)),
            ("train_speedup", Json::from(train_speedup)),
            ("infer_ws_ns_per_img", Json::from(ns_per_img(&r_infer, n_imgs))),
            ("batch_images", Json::from(n_batch)),
            ("batch_single_ns_per_img", Json::from(ns_per_img(&r_bsingle, n_batch))),
            ("batch_tile_ns_per_img", Json::from(ns_per_img(&r_btile, n_batch))),
            ("batch_tile_threads_ns_per_img", Json::from(ns_per_img(&r_bthr, n_batch))),
            ("threads", Json::from(thr)),
            ("tile_speedup", Json::from(tile_speedup)),
            ("tile_threads_speedup", Json::from(tile_thr_speedup)),
            (
                "modeled_tile_speedup",
                Json::from(host_tile_img_s(&cfg, TILE, 1) / host_tile_img_s(&cfg, 1, 1)),
            ),
            ("batch_tile_bf16_ns_per_img", Json::from(ns_per_img(&r_qbf16, n_batch))),
            ("batch_tile_int8_ns_per_img", Json::from(ns_per_img(&r_qint8, n_batch))),
            ("bf16_tile_speedup", Json::from(bf16_tile_speedup)),
            ("int8_tile_speedup", Json::from(int8_tile_speedup)),
            (
                "modeled_bf16_stream_speedup",
                Json::from(modeled_stream(QuantFormat::Bf16)),
            ),
            (
                "modeled_int8_stream_speedup",
                Json::from(modeled_stream(QuantFormat::Int8)),
            ),
            ("train_seq_ns_per_img", Json::from(ns_per_img(&r_tseq, n_batch))),
            ("train_batch_ns_per_img", Json::from(ns_per_img(&r_tbat, n_batch))),
            ("train_batch_threads_ns_per_img", Json::from(ns_per_img(&r_tthr, n_batch))),
            ("train_batch_speedup", Json::from(train_tile_speedup)),
            ("train_batch_threads_speedup", Json::from(train_thr_speedup)),
            (
                "modeled_train_tile_speedup",
                Json::from(host_tile_img_s(&cfg, TILE, 1) / host_tile_img_s(&cfg, 1, 1)),
            ),
        ]));
    }

    if opts.json {
        let report = Json::obj(vec![
            ("bench", Json::from("kernels")),
            ("source", Json::from("measured")),
            ("quick", Json::from(opts.quick)),
            ("threads", Json::from(opts.threads)),
            ("configs", Json::Arr(entries)),
        ]);
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_kernels.json");
        bh::write_json_report(&path, &report).expect("write BENCH_kernels.json");
        println!("wrote {}", path.display());
    }
}
