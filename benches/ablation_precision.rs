//! Precision ablation — the paper's future-work direction ("future
//! work can easily use other number representations") and the
//! StreamBrain custom-float results: accuracy vs storage format for
//! the streamed BCPNN state, plus the bandwidth/latency headroom
//! narrower words buy on the memory-bound kernels.
//!
//! Stacked configs (`toy-deep`) route through the `LayerGraph` twin of
//! `run_experiment`, so the ablation covers the deep quantize-on-write
//! path as well as the classic two-projection network.
//!
//!     cargo bench --bench ablation_precision
//!     cargo bench --bench ablation_precision -- --quick   # CI smoke

use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::config::by_name;
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::quant::{run_experiment, Format};
use bcpnn_accel::fpga::timing::active_synapses;

fn main() {
    let opts = bh::BenchOpts::from_args();
    println!("== precision ablation (quantize-on-write training) ==\n");

    let formats = [
        Format::F32,
        Format::Bf16,
        Format::F16,
        Format::Fixed { int_bits: 3, frac_bits: 12 },
        Format::Fixed { int_bits: 2, frac_bits: 6 },
        Format::Fixed { int_bits: 1, frac_bits: 3 },
    ];

    let names: &[&str] = if opts.quick {
        &["tiny", "toy-deep"]
    } else {
        &["tiny", "edge", "toy-deep"]
    };
    let (n_imgs, n_train, epochs) = if opts.quick { (96, 64, 1) } else { (384, 256, 2) };

    for name in names {
        let cfg = by_name(name).unwrap();
        let d = synth::generate(cfg.img_side, cfg.n_classes, n_imgs, 11, 0.15);
        let (train, test) = d.split(n_train);
        println!("{name} ({} classes, chance {:.0}%, {} layer(s)):", cfg.n_classes,
                 100.0 / cfg.n_classes as f64, cfg.n_layers());
        println!("  format  bits  test_acc  joint-array MB/img (vs f32)");
        let mb_f32 =
            16.0 * active_synapses(&cfg) as f64 / 1e6; // 4 arrays x 4 B
        for fmt in formats {
            let r = run_experiment(&cfg, &train, &test, epochs, fmt, 42);
            println!(
                "  {:<6} {:>4}  {:>7.1}%  {:>6.2} ({:.2}x)",
                r.format.name(),
                r.format.bits(),
                r.test_acc * 100.0,
                mb_f32 * r.traffic_ratio,
                r.traffic_ratio
            );
        }
        println!();
    }

    println!(
        "reading: bf16/f16/q3.12 halve the streamed joint arrays — the \
         memory-bound\ntrain kernels (Fig 6) would move ~2x up the \
         bandwidth roof; accuracy cost is\nwithin noise until aggressive \
         fixed-point (q1.3), matching the fixed-point\nBCPNN literature \
         (Johansson & Lansner 2004) and StreamBrain's custom floats."
    );
}
