//! Fig. 4 ablation: HBM partitioning + 512-bit bursts + merging.
//!
//! Sweeps partition count (1/2/4/8) and burst width (256/512) over the
//! joint-array stream of each model, reporting the per-image stream
//! time and the speedup over element-at-a-time access — reproducing
//! the paper's "reduces latency by a factor of about 64" for the
//! 4-way x 512-bit configuration, and why they stopped at 4
//! ("if we partition more, it will result in highly congested routing"
//! — modeled as the BRAM/fmax penalty of more channel buffers).
//!
//!     cargo bench --bench ablation_hbm

use bcpnn_accel::config::by_name;
use bcpnn_accel::fpga::device::FpgaDevice;
use bcpnn_accel::fpga::hbm::{packet_speedup, HbmModel};
use bcpnn_accel::fpga::timing::active_synapses;

fn main() {
    let dev = FpgaDevice::u55c();
    println!("== Fig 4 ablation: HBM partitioning & merging ==\n");

    for name in ["model1", "model2", "model3"] {
        let cfg = by_name(name).unwrap();
        let floats = 2 * active_synapses(&cfg); // read pij + w per image
        println!(
            "{name}: streaming {} floats/image of joint arrays @ 150 MHz kernel clock",
            floats
        );
        println!("  part  burst   floats/cyc  stream_ms  GB/s    speedup_vs_scalar");
        for &burst in &[256u32, 512u32] {
            for &p in &[1u32, 2, 4, 8] {
                let m = HbmModel { partitions: p, burst_bits: burst, kernel_freq_hz: 150e6 };
                let t_ms = m.stream_time_s(floats) * 1e3;
                let scalar = HbmModel { partitions: 1, burst_bits: 32, kernel_freq_hz: 150e6 };
                let speedup = scalar.stream_time_s(floats) / m.stream_time_s(floats);
                let marker = if p == 4 && burst == 512 { "  <- paper's config (x64)" } else { "" };
                println!(
                    "  {p:>4}  {burst:>5}   {:>9}  {:>8.3}  {:>6.1}  x{speedup:<6.1}{marker}",
                    m.floats_per_cycle(),
                    t_ms,
                    m.stream_bandwidth(&dev) / 1e9,
                );
            }
        }
        println!();
    }

    println!("theoretical packet speedups (paper: 'reduces latency by a factor of about 64'):");
    for &(p, b) in &[(1u32, 32u32), (1, 512), (4, 512), (8, 512)] {
        println!("  {p}-way x {b}-bit: x{}", packet_speedup(p, b));
    }

    // Why stop at 4: each extra channel costs buffers (BRAM) which
    // costs fmax (the estimator's congestion law). Marginal gain of
    // 8-way is halved stream time but ~6% fmax loss on an already
    // memory-bound kernel whose other stages don't speed up.
    println!("\nwhy 4-way (not 8): channel buffers raise BRAM -> fmax derates;");
    let cfg = by_name("model1").unwrap();
    for (p, extra_bram) in [(4u32, 0.0f64), (8, 64.0)] {
        let base = bcpnn_accel::fpga::estimator::estimate(
            &cfg,
            bcpnn_accel::fpga::device::KernelVersion::Train,
            &dev,
        );
        let bram = base.brams + extra_bram;
        let bram_pct = 100.0 * bram / dev.brams as f64;
        let f = (186.0 - 1.44 * bram_pct).clamp(60.0, 186.0);
        println!(
            "  {p}-way: BRAM {:.0} blocks ({:.0}%) -> fmax ~{:.0} MHz",
            bram, bram_pct, f
        );
    }
}
