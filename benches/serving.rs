//! Serving-path bench: end-to-end request latency decomposition.
//!
//!     cargo bench --bench serving
//!     cargo bench --bench serving -- --json       # + BENCH_serving.json
//!
//! Two sections, both measured on this host (the serving stack is pure
//! software; the device streams it batches onto are modeled elsewhere):
//!
//!  1. **InferenceServer + GraphBackend** — the `repro serve --host`
//!     path. N requests stream through the batching queue; the
//!     telemetry histograms decompose each request's end-to-end
//!     latency into queue wait (enqueue -> batch dispatch) and service
//!     (the batch's inference, shared by its members). The row printed
//!     is the `ServerReport` the CLI prints, plus the invariant check
//!     `e2e ~= wait + service` that `rust/tests/telemetry.rs` pins.
//!  2. **HybridExecutor** — the per-stage/per-shard queue-vs-compute
//!     decomposition on a stacked config across 3 simulated devices
//!     (`report::decomposition_table`).
//!
//! `--json` writes `BENCH_serving.json` at the repo root: the server
//! report and per-worker span stats, machine-readable (`to_json`).

use std::path::Path;
use std::time::Duration;

use bcpnn_accel::bcpnn::LayerGraph;
use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::cluster::{plan_hybrid, Fleet, HybridExecutor};
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::{GraphBackend, InferenceServer, ServerConfig, ServerReport};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::report;
use bcpnn_accel::util::json::Json;

/// Serve `n_requests` synthetic images through the host tile engine
/// behind the batching server and return the report.
fn server_section(n_requests: usize, threads: usize) -> ServerReport {
    let cfg = by_name("tiny").unwrap();
    let cfg_worker = cfg.clone();
    let server = InferenceServer::start(
        move || Ok(GraphBackend::new(LayerGraph::new(cfg_worker, 42), threads)),
        ServerConfig::default(),
    )
    .unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, n_requests, 7, 0.15);
    let pending: Vec<_> = data
        .images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &pending {
        let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let rep = server.shutdown();
    print!("{}", report::serve_decomposition(&rep));
    // The decomposition contract: per request e2e = queue wait +
    // service by construction, so the means must line up (slack for
    // scheduler noise and response-channel overhead).
    let sum = rep.queue_wait.mean_ms + rep.service.mean_ms;
    let gap = (rep.latency.mean_ms - sum).abs();
    let ok = gap <= 0.5 * rep.latency.mean_ms.max(0.5) + 2.0;
    println!(
        "  e2e mean {:.3} ms vs wait+service {:.3} ms: {}",
        rep.latency.mean_ms,
        sum,
        if ok { "PASS" } else { "FAIL" }
    );
    assert!(ok, "decomposition does not sum to e2e: {rep:?}");
    rep
}

/// Run the hybrid executor on a stacked config and return its
/// per-worker reports (printed as the decomposition table).
fn hybrid_section(n_images: usize) -> Vec<bcpnn_accel::cluster::WorkerReport> {
    let cfg = by_name("toy-deep").unwrap();
    let fleet = Fleet::homogeneous(&FpgaDevice::u55c(), 3);
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1).unwrap();
    let exec = HybridExecutor::new(LayerGraph::new(cfg.clone(), 42), &hp).unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, n_images, 7, 0.15);
    let r = bh::bench_for(
        &format!("HybridExecutor x{n_images} imgs (toy-deep, 3 devices)"),
        Duration::from_millis(60),
        || {
            let out = exec.infer_batch(&data.images).unwrap();
            std::hint::black_box(out.len());
        },
    );
    println!("\n{}", bh::header());
    println!("{}  ({:.0} img/s; host-core bound)", r.row(), r.throughput(n_images as u64));
    let workers = exec.shutdown();
    print!("{}", report::decomposition_table(&workers));
    workers
}

fn main() {
    let opts = bh::BenchOpts::from_args();
    let n_requests = if opts.quick { 64 } else { 512 };
    let n_images = if opts.quick { 16 } else { 64 };

    println!("== serving path: queue-vs-compute decomposition ==");
    println!(
        "\n-- InferenceServer + GraphBackend (tiny, {n_requests} requests, {} thread(s)) --",
        opts.threads
    );
    let rep = server_section(n_requests, opts.threads);

    println!("\n-- HybridExecutor per-worker decomposition --");
    let workers = hybrid_section(n_images);

    if opts.json {
        let report = Json::obj(vec![
            ("bench", Json::from("serving")),
            ("source", Json::from("measured")),
            ("threads", Json::from(opts.threads)),
            ("requests", Json::from(n_requests)),
            ("server", rep.to_json()),
            (
                "hybrid",
                Json::obj(vec![
                    ("config", Json::from("toy-deep")),
                    ("devices", Json::from(3usize)),
                    ("images", Json::from(n_images)),
                    (
                        "workers",
                        Json::Arr(workers.iter().map(|w| w.to_json()).collect()),
                    ),
                ]),
            ),
        ]);
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
        bh::write_json_report(&path, &report).expect("write BENCH_serving.json");
        println!("\nwrote {}", path.display());
    }
}
