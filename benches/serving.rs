//! Serving-path bench: end-to-end request latency decomposition.
//!
//!     cargo bench --bench serving
//!     cargo bench --bench serving -- --json       # + BENCH_serving.json
//!
//! Three sections, all measured on this host (the serving stack is
//! pure software; the device streams it batches onto are modeled
//! elsewhere):
//!
//!  1. **InferenceServer + GraphBackend** — the `repro serve --host`
//!     path. N requests stream through the batching queue; the
//!     telemetry histograms decompose each request's end-to-end
//!     latency into queue wait (enqueue -> batch dispatch) and service
//!     (the batch's inference, shared by its members). The row printed
//!     is the `ServerReport` the CLI prints, plus the invariant check
//!     `e2e ~= wait + service` that `rust/tests/telemetry.rs` pins.
//!  2. **Overload + shed admission** — an open-loop arrival stream at
//!     2x the backend's service rate against a short queue with
//!     `Admission::Shed`: the front door must reject the excess with
//!     typed `Overloaded` while the accepted requests keep a bounded
//!     p99 (the queue can never hold more than `queue_depth` of
//!     backlog). Reports shed rate and p99-with-shedding.
//!  3. **HybridExecutor** — the per-stage/per-shard queue-vs-compute
//!     decomposition on a stacked config across 3 simulated devices
//!     (`report::decomposition_table`).
//!
//! `--json` writes `BENCH_serving.json` at the repo root: the server
//! report and per-worker span stats, machine-readable (`to_json`).

use std::path::Path;
use std::time::{Duration, Instant};

use bcpnn_accel::bcpnn::LayerGraph;
use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::cluster::{plan_hybrid, Fleet, HybridExecutor};
use bcpnn_accel::config::by_name;
use bcpnn_accel::coordinator::{
    Admission, GraphBackend, InferBackend, InferenceServer, ServeError, ServerConfig, ServerReport,
};
use bcpnn_accel::data::synth;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::report;
use bcpnn_accel::util::json::Json;

/// Serve `n_requests` synthetic images through the host tile engine
/// behind the batching server and return the report.
fn server_section(n_requests: usize, threads: usize) -> ServerReport {
    let cfg = by_name("tiny").unwrap();
    let cfg_worker = cfg.clone();
    let server = InferenceServer::start(
        move || Ok(GraphBackend::new(LayerGraph::new(cfg_worker, 42), threads)),
        ServerConfig::default(),
    )
    .unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, n_requests, 7, 0.15);
    let pending: Vec<_> = data
        .images
        .iter()
        .map(|img| server.submit(img.clone()).unwrap())
        .collect();
    for rx in &pending {
        let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let rep = server.shutdown();
    print!("{}", report::serve_decomposition(&rep));
    // The decomposition contract: per request e2e = queue wait +
    // service by construction, so the means must line up (slack for
    // scheduler noise and response-channel overhead).
    let sum = rep.queue_wait.mean_ms + rep.service.mean_ms;
    let gap = (rep.latency.mean_ms - sum).abs();
    let ok = gap <= 0.5 * rep.latency.mean_ms.max(0.5) + 2.0;
    println!(
        "  e2e mean {:.3} ms vs wait+service {:.3} ms: {}",
        rep.latency.mean_ms,
        sum,
        if ok { "PASS" } else { "FAIL" }
    );
    assert!(ok, "decomposition does not sum to e2e: {rep:?}");
    rep
}

/// Fixed-cost backend for the overload section: the service rate is
/// known exactly (`batch` images per `sleep`), so the offered load can
/// be set to a precise multiple of it.
struct FixedCostBackend {
    batch: usize,
    sleep: Duration,
}

impl InferBackend for FixedCostBackend {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer_batch(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.sleep);
        Ok(images.iter().map(|img| vec![img[0]]).collect())
    }
}

/// Overload stats returned for the JSON report.
struct OverloadStats {
    offered: u64,
    served: u64,
    shed: u64,
    p99_ms: f64,
    queue_depth: usize,
}

/// Open-loop arrivals at 2x the service rate against a short queue
/// with shed admission: measure the shed rate and the p99 of what was
/// actually served.
fn overload_section(n_requests: usize) -> OverloadStats {
    let batch = 4usize;
    let sleep = Duration::from_millis(2);
    let queue_depth = 16usize;
    // Capacity: batch/sleep = 2000 img/s. Offer 2x that.
    let interval = sleep / (2 * batch as u32);
    let server = InferenceServer::start(
        move || Ok(FixedCostBackend { batch, sleep }),
        ServerConfig {
            queue_depth,
            flush_timeout: Duration::from_micros(500),
            admission: Admission::Shed,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let mut tickets = Vec::with_capacity(n_requests);
    let mut shed = 0u64;
    let t0 = Instant::now();
    for i in 0..n_requests {
        // Open loop: arrivals keep their schedule no matter how far
        // behind the server falls — the defining trait of overload.
        while t0.elapsed() < interval * i as u32 {
            std::hint::spin_loop();
        }
        match server.submit(vec![i as f32]) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    for t in &tickets {
        t.wait().expect("accepted request must be answered");
    }
    let rep = server.shutdown();
    let stats = OverloadStats {
        offered: n_requests as u64,
        served: rep.served,
        shed,
        p99_ms: rep.latency.p99_ms,
        queue_depth,
    };
    println!(
        "  offered {} at 2x capacity (queue {}): served {}  shed {} ({:.1}%)",
        stats.offered,
        stats.queue_depth,
        stats.served,
        stats.shed,
        100.0 * stats.shed as f64 / stats.offered as f64
    );
    println!(
        "  p99 with shedding {:.3} ms (queue bound: {} img backlog x {:.1} ms/batch)",
        stats.p99_ms,
        stats.queue_depth,
        sleep.as_secs_f64() * 1e3
    );
    assert_eq!(stats.served + stats.shed, stats.offered, "typed sheds must partition arrivals");
    stats
}

/// Run the hybrid executor on a stacked config and return its
/// per-worker reports (printed as the decomposition table).
fn hybrid_section(n_images: usize) -> Vec<bcpnn_accel::cluster::WorkerReport> {
    let cfg = by_name("toy-deep").unwrap();
    let fleet = Fleet::homogeneous(&FpgaDevice::u55c(), 3);
    let hp = plan_hybrid(&cfg, &fleet, KernelVersion::Infer, 0.1).unwrap();
    let exec = HybridExecutor::new(LayerGraph::new(cfg.clone(), 42), &hp).unwrap();
    let data = synth::generate(cfg.img_side, cfg.n_classes, n_images, 7, 0.15);
    let r = bh::bench_for(
        &format!("HybridExecutor x{n_images} imgs (toy-deep, 3 devices)"),
        Duration::from_millis(60),
        || {
            let out = exec.infer_batch(&data.images).unwrap();
            std::hint::black_box(out.len());
        },
    );
    println!("\n{}", bh::header());
    println!("{}  ({:.0} img/s; host-core bound)", r.row(), r.throughput(n_images as u64));
    let workers = exec.shutdown();
    print!("{}", report::decomposition_table(&workers));
    workers
}

fn main() {
    let opts = bh::BenchOpts::from_args();
    let n_requests = if opts.quick { 64 } else { 512 };
    let n_images = if opts.quick { 16 } else { 64 };

    println!("== serving path: queue-vs-compute decomposition ==");
    println!(
        "\n-- InferenceServer + GraphBackend (tiny, {n_requests} requests, {} thread(s)) --",
        opts.threads
    );
    let rep = server_section(n_requests, opts.threads);

    let n_overload = if opts.quick { 200 } else { 400 };
    println!("\n-- overload: open-loop 2x capacity, shed admission --");
    let overload = overload_section(n_overload);

    println!("\n-- HybridExecutor per-worker decomposition --");
    let workers = hybrid_section(n_images);

    if opts.json {
        let report = Json::obj(vec![
            ("bench", Json::from("serving")),
            ("source", Json::from("measured")),
            ("threads", Json::from(opts.threads)),
            ("requests", Json::from(n_requests)),
            ("server", rep.to_json()),
            (
                "overload",
                Json::obj(vec![
                    ("offered", Json::from(overload.offered as f64)),
                    ("served", Json::from(overload.served as f64)),
                    ("shed", Json::from(overload.shed as f64)),
                    (
                        "shed_rate",
                        Json::from(overload.shed as f64 / overload.offered as f64),
                    ),
                    ("p99_with_shedding_ms", Json::from(overload.p99_ms)),
                    ("queue_depth", Json::from(overload.queue_depth)),
                ]),
            ),
            (
                "hybrid",
                Json::obj(vec![
                    ("config", Json::from("toy-deep")),
                    ("devices", Json::from(3usize)),
                    ("images", Json::from(n_images)),
                    (
                        "workers",
                        Json::Arr(workers.iter().map(|w| w.to_json()).collect()),
                    ),
                ]),
            ),
        ]);
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
        bh::write_json_report(&path, &report).expect("write BENCH_serving.json");
        println!("\nwrote {}", path.display());
    }
}
