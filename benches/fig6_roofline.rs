//! Fig. 6 bench: roofline operating points for every model/build,
//! plus the plot series (AI sweep) needed to redraw the figure.
//!
//!     cargo bench --bench fig6_roofline

use bcpnn_accel::config::by_name;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::report;
use bcpnn_accel::roofline;

fn main() {
    let dev = FpgaDevice::u55c();
    println!("{}", report::fig6(&["model1", "model2", "model3"]).unwrap());

    // Series for replotting Fig. 6: per train-build frequency, the
    // roofline; then each model's (AI, attained) point.
    println!("plot series (CSV): freq_mhz,ai,attainable_gflops");
    for m in ["model1", "model2", "model3"] {
        let cfg = by_name(m).unwrap();
        let op = roofline::operating_point(&cfg, KernelVersion::Train, &dev);
        let mut ai = 0.05f64;
        while ai <= 16.0 {
            println!(
                "{:.1},{:.3},{:.3}",
                op.freq_mhz,
                ai,
                roofline::attainable_flops(&dev, op.freq_mhz * 1e6, ai) / 1e9
            );
            ai *= 2.0;
        }
    }
    println!("points (CSV): model,version,ai,attained_gflops,peak_gflops");
    for m in ["model1", "model2", "model3"] {
        let cfg = by_name(m).unwrap();
        for v in [KernelVersion::Train, KernelVersion::Struct] {
            let op = roofline::operating_point(&cfg, v, &dev);
            println!(
                "{m},{},{:.3},{:.3},{:.3}",
                v.name(),
                op.ai,
                op.attained_flops / 1e9,
                op.peak_flops / 1e9
            );
        }
    }

    // Sanity recap mirroring the paper's Fig. 6 narrative.
    let m2 = roofline::operating_point(
        &by_name("model2").unwrap(), KernelVersion::Train, &dev);
    let m1 = roofline::operating_point(
        &by_name("model1").unwrap(), KernelVersion::Train, &dev);
    println!(
        "\nnarrative checks: model2 attained {:.1} GF/s vs model1 {:.1} GF/s \
         (paper: model 2 'lies closer to peak performance'... at its lower clock)",
        m2.attained_flops / 1e9,
        m1.attained_flops / 1e9
    );
}
