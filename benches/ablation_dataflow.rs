//! Fig. 3 ablation: sequential vs stream-dataflow execution — the
//! paper's "~70% performance improvement" from Optimization #1+#2.
//!
//! Two experiments:
//!  1. **cycle-accurate** (the FPGA claim's currency): the kernel
//!     stage chain simulated sequentially vs with dataflow FIFOs;
//!  2. **wall-clock**: the thread pipeline on real BCPNN stage
//!     functions (informational on this 1-core host — overlap needs
//!     cores; the cycle simulation is the reproduction).
//!
//!     cargo bench --bench ablation_dataflow

use std::sync::Arc;

use bcpnn_accel::bcpnn::Network;
use bcpnn_accel::bench_harness as bh;
use bcpnn_accel::config::by_name;
use bcpnn_accel::data::encode::encode_image;
use bcpnn_accel::data::synth;
use bcpnn_accel::stream::depth::{minimal_depths, simulate, StageSpec};
use bcpnn_accel::stream::Pipeline;

fn kernel_chain(mc_h: usize) -> Vec<StageSpec> {
    vec![
        StageSpec::streaming("hbm_read", 1),
        StageSpec::streaming("support", 1),
        StageSpec::with_barrier("softmax", 1, mc_h.div_ceil(16) as u64),
        StageSpec::streaming("plasticity", 1),
        StageSpec::streaming("hbm_write", 1),
    ]
}

fn main() {
    println!("== Fig 3 ablation: sequential vs dataflow ==\n");

    println!("cycle-level (the paper's claim):");
    println!("model    seq_cycles   dataflow_cycles  improvement  depths");
    for name in ["model1", "model2", "model3", "edge"] {
        let cfg = by_name(name).unwrap();
        let stages = kernel_chain(cfg.mc_h);
        let items = 4096u64;
        let seq: u64 = items * stages.iter().map(|s| s.cycles_per_item).sum::<u64>();
        let depths = minimal_depths(&stages, items, 0.05);
        let df = simulate(&stages, &depths, items);
        println!(
            "{name:<8} {seq:>10}   {:>15}  {:>+9.0}%  {depths:?}",
            df.total_cycles,
            100.0 * (seq as f64 / df.total_cycles as f64 - 1.0),
        );
    }
    println!(
        "(paper measures ~70% on hardware, where stages share DSP/BRAM \
         resources; the\n cycle model gives the idealized upper bound — \
         dataflow wins in both, as claimed)\n"
    );

    // Wall-clock thread pipeline (informational on a 1-core host).
    println!("wall-clock thread pipeline (edge config, 512 images):");
    println!("{}", bh::header());
    let cfg = by_name("edge").unwrap();
    let net = Arc::new(Network::new(cfg.clone(), 5));
    let d = synth::generate(cfg.img_side, cfg.n_classes, 512, 7, 0.15);

    let n1 = net.clone();
    let images = d.images.clone();
    let r = bh::bench("sequential (encode+support+softmax+out)", 1, 5, move || {
        for img in &images {
            let x = encode_image(img);
            let mut s = n1.support(&x);
            Network::hc_softmax(&mut s, n1.cfg.hc_h, n1.cfg.mc_h, n1.cfg.gain);
            std::hint::black_box(n1.output_activity(&s));
        }
    });
    println!("{}", r.row());
    let seq_mean = r.mean;

    let r = bh::bench("dataflow pipeline (3 stages, depth 32)", 1, 5, || {
        let n = net.clone();
        let n2 = net.clone();
        let (out, _) = Pipeline::source("img", 32, d.images.clone())
            .stage("encode", 32, |img: Vec<f32>| encode_image(&img))
            .stage("support", 32, move |x: Vec<f32>| n.support(&x))
            .stage("act", 32, move |mut s: Vec<f32>| {
                Network::hc_softmax(&mut s, n2.cfg.hc_h, n2.cfg.mc_h, n2.cfg.gain);
                n2.output_activity(&s)
            })
            .collect();
        std::hint::black_box(out.len());
    });
    println!("{}", r.row());
    println!(
        "wall-clock ratio: {:.2}x (1 CPU core: thread overlap impossible; \
         see cycle-level numbers above for the architecture claim)",
        seq_mean.as_secs_f64() / r.mean.as_secs_f64()
    );
}
