//! Table 3 bench: the resource estimator vs the paper's synthesis
//! results, with per-cell relative error — the reproduction-quality
//! scoreboard for the device model.
//!
//!     cargo bench --bench table3_resources

use bcpnn_accel::config::by_name;
use bcpnn_accel::fpga::device::{FpgaDevice, KernelVersion};
use bcpnn_accel::fpga::estimator::estimate;
use bcpnn_accel::report;

/// Paper Table 3 (model, version, LUT, FF, DSP, BRAM, MHz).
const PAPER: &[(&str, &str, u64, u64, u64, f64, f64)] = &[
    ("model1", "infer", 174_400, 257_462, 550, 327.5, 200.0),
    ("model1", "train", 454_024, 546_419, 3_573, 437.5, 150.0),
    ("model1", "struct", 475_074, 574_657, 3_765, 473.5, 147.3),
    ("model2", "infer", 177_201, 261_754, 644, 701.5, 160.0),
    ("model2", "train", 459_419, 488_973, 3_573, 862.5, 110.0),
    ("model2", "struct", 479_801, 513_057, 3_765, 898.5, 107.8),
    ("model3", "infer", 180_365, 259_592, 640, 1_419.0, 84.4),
    ("model3", "train", 463_580, 406_798, 3_573, 1_568.5, 60.0),
    ("model3", "struct", 481_731, 430_927, 3_765, 1_604.5, 60.0),
];

fn version_of(v: &str) -> KernelVersion {
    match v {
        "infer" => KernelVersion::Infer,
        "train" => KernelVersion::Train,
        _ => KernelVersion::Struct,
    }
}

fn pct(got: f64, want: f64) -> f64 {
    100.0 * (got - want) / want
}

fn main() {
    println!("{}", report::table3(&["model1", "model2", "model3"]).unwrap());

    println!("estimator vs paper Table 3 (relative error %):");
    println!("model    version   LUT     FF      DSP     BRAM    freq");
    let dev = FpgaDevice::u55c();
    let mut worst: (f64, String) = (0.0, String::new());
    for &(m, v, lut, ff, dsp, bram, mhz) in PAPER {
        let u = estimate(&by_name(m).unwrap(), version_of(v), &dev);
        let errs = [
            pct(u.luts as f64, lut as f64),
            pct(u.ffs as f64, ff as f64),
            pct(u.dsps as f64, dsp as f64),
            pct(u.brams, bram),
            pct(u.freq_mhz, mhz),
        ];
        println!(
            "{m:<8} {v:<8} {:>+6.1}% {:>+6.1}% {:>+6.1}% {:>+6.1}% {:>+6.1}%",
            errs[0], errs[1], errs[2], errs[3], errs[4]
        );
        for (i, e) in errs.iter().enumerate() {
            // FF (index 1) excluded from the scoreboard: register
            // packing is synthesis-dependent (documented in estimator).
            if i != 1 && e.abs() > worst.0 {
                worst = (e.abs(), format!("{m}/{v} col {i}"));
            }
        }
    }
    println!("\nworst non-FF cell error: {:.1}% ({})", worst.0, worst.1);
    println!("reduced configs (what this host actually executes):");
    println!("{}", report::table3(&["tiny", "small", "edge"]).unwrap());
}
