"""AOT lowering: every (config, mode) lowers to parseable HLO text with a
manifest signature that matches the traced function exactly."""

import json
import pathlib

import pytest

from compile import aot, model
from compile.configs import CONFIGS, MODES

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.mark.parametrize("name", ["tiny"])
@pytest.mark.parametrize("mode", MODES)
def test_lower_artifact_smoke(name, mode):
    cfg = CONFIGS[name]
    text, entry = aot.lower_artifact(cfg, mode)
    # HLO text structure, not a serialized proto.
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    assert entry["mode"] == mode
    assert entry["config"]["n_in"] == cfg.n_in
    assert len(entry["inputs"]) == len(model.example_args(cfg, mode))


@pytest.mark.parametrize("mode", MODES)
def test_signature_names_cover_args(mode):
    cfg = CONFIGS["tiny"]
    args = model.example_args(cfg, mode)
    names = aot._INPUT_NAMES[mode]
    assert len(names) == len(args)


def test_example_args_shapes_infer():
    cfg = CONFIGS["small"]
    wij, bj, who, bk, mask, imgs = model.example_args(cfg, "infer")
    assert wij.shape == (cfg.n_in, cfg.n_h)
    assert bj.shape == (cfg.n_h,)
    assert who.shape == (cfg.n_h, cfg.n_out)
    assert bk.shape == (cfg.n_out,)
    assert mask.shape == (cfg.hc_in, cfg.hc_h)
    assert imgs.shape == (cfg.batch, cfg.hc_in)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        model.build_fn(CONFIGS["tiny"], "nope")
    with pytest.raises(ValueError):
        model.example_args(CONFIGS["tiny"], "nope")


@pytest.mark.skipif(not (ART / "manifest.json").exists(),
                    reason="run `make artifacts` first")
def test_manifest_consistent_with_files():
    manifest = json.loads((ART / "manifest.json").read_text())
    assert manifest["artifacts"], "empty manifest"
    for key, entry in manifest["artifacts"].items():
        f = ART / entry["file"]
        assert f.exists(), f"missing artifact file {f}"
        text = f.read_text()
        assert text.startswith("HloModule")
        # Entry-computation parameter count must match the manifest inputs.
        assert entry["config"]["batch"] >= 1
        got_params = text.count("parameter(")
        assert got_params >= len(entry["inputs"]), (
            key, got_params, len(entry["inputs"]))


@pytest.mark.skipif(not (ART / "manifest.json").exists(),
                    reason="run `make artifacts` first")
def test_manifest_sha256_matches():
    import hashlib
    manifest = json.loads((ART / "manifest.json").read_text())
    for key, entry in manifest["artifacts"].items():
        text = (ART / entry["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == entry["sha256"], key


@pytest.mark.parametrize("name", ["model1", "model2", "model3"])
def test_paper_shape_models_lower(name):
    """The paper-shape models (Table 1) lower cleanly — the `--full`
    AOT path. Lowering is shape-symbolic so this stays fast even at
    1568x4096 joint arrays."""
    cfg = CONFIGS[name]
    text, entry = aot.lower_artifact(cfg, "train_unsup")
    assert text.startswith("HloModule")
    pij = next(t for t in entry["inputs"] if t["name"] == "pij")
    assert pij["shape"] == [cfg.n_in, cfg.n_h]
    # Full-array tiles on the interpret path (perf default).
    assert entry["config"]["tile_in"] == cfg.n_in
    assert entry["config"]["tile_h"] == cfg.n_h
