"""L2 correctness: the full BCPNN model, Pallas path vs oracle path.

Covers: pallas/ref A/B at every batched entry point, probabilistic
invariants of the dynamics, and an end-to-end learning sanity check
(unsupervised + supervised training separates synthetic classes well
above chance) — the python mirror of the rust quickstart example.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, model
from compile.configs import CONFIGS

CFG = CONFIGS["tiny"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


@pytest.fixture(scope="module")
def mask():
    return model.init_mask(CFG, seed=3)


@pytest.fixture(scope="module")
def batch():
    imgs, labels = datasets.generate(CFG.img_side, CFG.n_classes,
                                     CFG.batch, seed=7)
    return jnp.asarray(imgs), jnp.asarray(labels)


# ------------------------------------------------------------ encoding


def test_encode_image_hc_sums_to_one():
    img = jnp.linspace(0, 1, CFG.hc_in)
    x = model.encode_image(img, CFG).reshape(CFG.hc_in, CFG.mc_in)
    np.testing.assert_allclose(np.sum(x, axis=1), np.ones(CFG.hc_in),
                               atol=1e-6)


def test_encode_image_clips_out_of_range():
    img = jnp.array([-0.5, 1.5] + [0.0] * (CFG.hc_in - 2))
    x = model.encode_image(img, CFG)
    assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0


def test_expand_mask_shape_and_blocks(mask):
    m = model.expand_mask(mask, CFG)
    assert m.shape == (CFG.n_in, CFG.n_h)
    # Unit-level mask is constant within each (input HC, hidden HC) block.
    m4 = np.asarray(m).reshape(CFG.hc_in, CFG.mc_in, CFG.hc_h, CFG.mc_h)
    assert np.all(m4 == m4[:, :1, :, :1])


def test_init_mask_exact_sparsity(mask):
    col_sums = np.asarray(mask).sum(axis=0)
    assert np.all(col_sums == CFG.nact_hi)


def test_init_params_uniform_weights_are_zero():
    """With jitter off: independent uniform traces => w ~ 0."""
    p = model.init_params(CFG, jitter=0.0)
    assert float(jnp.max(jnp.abs(p["wij"]))) < 1e-3


def test_init_params_jitter_breaks_symmetry(params):
    """Default init must differentiate minicolumns within each hidden HC."""
    w = np.asarray(params["wij"]).reshape(CFG.n_in, CFG.hc_h, CFG.mc_h)
    assert np.std(w, axis=2).max() > 1e-3


# ------------------------------------------------ pallas vs oracle A/B


@pytest.mark.parametrize("mode", ["infer", "train_unsup", "train_sup"])
def test_pallas_vs_ref_entry_points(mode, params, mask, batch):
    imgs, labels = batch
    args_by_mode = {
        "infer": (params["wij"], params["bj"], params["who"], params["bk"],
                  mask, imgs),
        "train_unsup": (params["pi"], params["pj"], params["pij"], mask,
                        imgs),
        "train_sup": (params["wij"], params["bj"], mask, params["qi"],
                      params["qk"], params["qik"], params["who"],
                      params["bk"], imgs, labels),
    }
    f_pallas = jax.jit(model.build_fn(CFG, mode, use_pallas=True))
    f_ref = jax.jit(model.build_fn(CFG, mode, use_pallas=False))
    got = f_pallas(*args_by_mode[mode])
    want = f_ref(*args_by_mode[mode])
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-4, atol=2e-5)


# ----------------------------------------------------------- invariants


def test_infer_probs_are_distributions(params, mask, batch):
    imgs, _ = batch
    (probs,) = jax.jit(model.build_fn(CFG, "infer"))(
        params["wij"], params["bj"], params["who"], params["bk"], mask, imgs)
    probs = np.asarray(probs)
    assert probs.shape == (CFG.batch, CFG.n_out)
    np.testing.assert_allclose(probs.sum(axis=1), np.ones(CFG.batch),
                               atol=1e-5)
    assert np.all(probs >= 0)


def test_train_unsup_traces_remain_probabilities(params, mask, batch):
    imgs, _ = batch
    out = jax.jit(model.build_fn(CFG, "train_unsup"))(
        params["pi"], params["pj"], params["pij"], mask, imgs)
    pi, pj, pij = (np.asarray(o) for o in out[:3])
    for arr in (pi, pj, pij):
        assert np.all(arr > 0) and np.all(arr < 1)
    # Marginals still sum to ~1 within each hypercolumn.
    np.testing.assert_allclose(
        pi.reshape(CFG.hc_in, CFG.mc_in).sum(axis=1),
        np.ones(CFG.hc_in), atol=1e-4)
    np.testing.assert_allclose(
        pj.reshape(CFG.hc_h, CFG.mc_h).sum(axis=1),
        np.ones(CFG.hc_h), atol=1e-4)


def test_train_unsup_is_online_not_batch(params, mask, batch):
    """Order sensitivity: streaming semantics => permuting the batch
    changes the final traces (unlike a batch-gradient method)."""
    imgs, _ = batch
    f = jax.jit(model.build_fn(CFG, "train_unsup"))
    out1 = f(params["pi"], params["pj"], params["pij"], mask, imgs)
    out2 = f(params["pi"], params["pj"], params["pij"], mask, imgs[::-1])
    assert not np.allclose(np.asarray(out1[2]), np.asarray(out2[2]),
                           atol=1e-7)


def test_masked_connections_keep_zero_weightless_support(params, mask, batch):
    """Hidden activity must not depend on weights of masked connections."""
    imgs, _ = batch
    f = jax.jit(model.build_fn(CFG, "infer"))
    (p1,) = f(params["wij"], params["bj"], params["who"], params["bk"],
              mask, imgs)
    # Corrupt weights only where the mask is 0 -> identical output.
    m_unit = np.asarray(model.expand_mask(mask, CFG))
    wij = np.asarray(params["wij"]).copy()
    wij[m_unit == 0] = 1e3
    (p2,) = f(jnp.asarray(wij), params["bj"], params["who"], params["bk"],
              mask, imgs)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)


# ------------------------------------------------------- learning sanity


def _train(cfg, epochs, n_train, n_test, seed=11):
    imgs, labels = datasets.generate(cfg.img_side, cfg.n_classes,
                                     n_train + n_test, seed=seed)
    imgs, labels = jnp.asarray(imgs), jnp.asarray(labels)
    tr_i, te_i = imgs[:n_train], imgs[n_train:]
    tr_l, te_l = labels[:n_train], labels[n_train:]

    params = model.init_params(cfg)
    mask = model.init_mask(cfg, seed=seed)
    unsup = jax.jit(model.build_fn(cfg, "train_unsup"))
    sup = jax.jit(model.build_fn(cfg, "train_sup"))
    infer = jax.jit(model.build_fn(cfg, "infer"))

    pi, pj, pij = params["pi"], params["pj"], params["pij"]
    wij, bj = params["wij"], params["bj"]
    nb = n_train // cfg.batch
    for _ in range(epochs):
        for b in range(nb):
            sl = slice(b * cfg.batch, (b + 1) * cfg.batch)
            pi, pj, pij, wij, bj = unsup(pi, pj, pij, mask, tr_i[sl])
    qi, qk, qik = params["qi"], params["qk"], params["qik"]
    who, bk = params["who"], params["bk"]
    for b in range(nb):
        sl = slice(b * cfg.batch, (b + 1) * cfg.batch)
        qi, qk, qik, who, bk = sup(wij, bj, mask, qi, qk, qik, who, bk,
                                   tr_i[sl], tr_l[sl])

    def acc(xs, ys):
        correct = 0
        for b in range(len(ys) // cfg.batch):
            sl = slice(b * cfg.batch, (b + 1) * cfg.batch)
            (probs,) = infer(wij, bj, who, bk, mask, xs[sl])
            correct += int(np.sum(np.argmax(np.asarray(probs), 1)
                                  == np.asarray(ys[sl])))
        return correct / (len(ys) // cfg.batch * cfg.batch)

    return acc(tr_i, tr_l), acc(te_i, te_l)


def test_learning_beats_chance():
    """End-to-end learning: synthetic classes separated well above chance
    (the python mirror of examples/quickstart.rs)."""
    tr, te = _train(CFG, epochs=2, n_train=128, n_test=64)
    chance = 1.0 / CFG.n_classes
    assert tr > chance + 0.15, f"train acc {tr} vs chance {chance}"
    assert te > chance + 0.10, f"test acc {te} vs chance {chance}"
