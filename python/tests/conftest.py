"""Shared fixtures for the build-time python test suite."""

import os
import sys

import jax
import pytest

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(1234)
