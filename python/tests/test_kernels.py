"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the CORE correctness signal for the compute layer: the AOT
artifacts embed exactly these kernels, so allclose here + the rust
runtime loading the artifacts = end-to-end numerics coverage.

hypothesis sweeps the shape/dtype/parameter space (hypercolumn counts,
minicolumn widths, tile sizes, alpha/eps/gain) beyond the hand-picked
cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import CONFIGS
from compile.kernels import hc_softmax, plasticity, ref, support

ATOL = 1e-5
RTOL = 1e-5


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _mk_support_inputs(seed, n_in, n_h, density=0.5):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = _rand(k[0], n_in, n_h)
    x = jax.nn.softmax(_rand(k[1], n_in))
    m = (jax.random.uniform(k[2], (n_in, n_h)) < density).astype(jnp.float32)
    b = _rand(k[3], n_h)
    return w, x, m, b


# ---------------------------------------------------------------- support


@pytest.mark.parametrize("n_in,n_h", [(16, 16), (128, 64), (288, 128),
                                      (64, 256), (96, 32)])
def test_support_matches_ref(n_in, n_h):
    w, x, m, b = _mk_support_inputs(0, n_in, n_h)
    got = support(w, x, m, b)
    want = ref.support_ref(w, x, m, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("tile_in,tile_h", [(8, 8), (16, 64), (64, 16),
                                            (128, 128), (32, 8)])
def test_support_tile_invariance(tile_in, tile_h):
    """Result must not depend on the packet (tile) decomposition."""
    n_in, n_h = 128, 128
    w, x, m, b = _mk_support_inputs(1, n_in, n_h)
    got = support(w, x, m, b, tile_in=tile_in, tile_h=tile_h)
    want = ref.support_ref(w, x, m, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_support_empty_mask_gives_bias():
    n_in, n_h = 32, 16
    w, x, _, b = _mk_support_inputs(2, n_in, n_h)
    m = jnp.zeros((n_in, n_h), jnp.float32)
    np.testing.assert_allclose(support(w, x, m, b), b, rtol=RTOL, atol=ATOL)


def test_support_full_mask_is_matvec():
    n_in, n_h = 32, 16
    w, x, _, b = _mk_support_inputs(3, n_in, n_h)
    m = jnp.ones((n_in, n_h), jnp.float32)
    np.testing.assert_allclose(
        support(w, x, m, b), b + w.T @ x, rtol=RTOL, atol=ATOL
    )


def test_support_rejects_nondividing_tiles():
    w, x, m, b = _mk_support_inputs(4, 30, 16)
    with pytest.raises(AssertionError):
        support(w, x, m, b, tile_in=16, tile_h=16)


@settings(max_examples=25, deadline=None)
@given(
    hc=st.integers(2, 8), mc=st.integers(2, 16),
    nh=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 2**16),
)
def test_support_hypothesis_shapes(hc, mc, nh, seed):
    n_in = hc * mc
    w, x, m, b = _mk_support_inputs(seed, n_in, nh)
    got = support(w, x, m, b)
    want = ref.support_ref(w, x, m, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- softmax


@pytest.mark.parametrize("n_hc,n_mc", [(1, 4), (4, 16), (32, 128), (8, 2),
                                       (16, 32)])
def test_softmax_matches_ref(n_hc, n_mc):
    s = _rand(jax.random.PRNGKey(5), n_hc * n_mc)
    got = hc_softmax(s, n_hc=n_hc, n_mc=n_mc)
    want = ref.hc_softmax_ref(s, n_hc, n_mc)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_softmax_each_hc_sums_to_one():
    n_hc, n_mc = 8, 16
    s = 10.0 * _rand(jax.random.PRNGKey(6), n_hc * n_mc)
    y = hc_softmax(s, n_hc=n_hc, n_mc=n_mc).reshape(n_hc, n_mc)
    np.testing.assert_allclose(np.sum(y, axis=1), np.ones(n_hc),
                               rtol=1e-6, atol=1e-6)


def test_softmax_gain_sharpens():
    """Higher gain concentrates mass on the max minicolumn."""
    n_hc, n_mc = 4, 8
    s = _rand(jax.random.PRNGKey(7), n_hc * n_mc)
    y1 = hc_softmax(s, n_hc=n_hc, n_mc=n_mc, gain=1.0).reshape(n_hc, n_mc)
    y4 = hc_softmax(s, n_hc=n_hc, n_mc=n_mc, gain=4.0).reshape(n_hc, n_mc)
    assert np.all(np.max(y4, axis=1) >= np.max(y1, axis=1) - 1e-6)


def test_softmax_extreme_supports_stable():
    """Numerical stability: huge positive/negative supports, no NaN."""
    s = jnp.array([1e4, -1e4, 0.0, 1e4, -30.0, 30.0, 0.0, 0.0], jnp.float32)
    y = hc_softmax(s, n_hc=2, n_mc=4)
    assert np.all(np.isfinite(np.asarray(y)))


@settings(max_examples=25, deadline=None)
@given(n_hc=st.integers(1, 12), n_mc=st.sampled_from([2, 4, 8, 16, 64]),
       gain=st.floats(0.25, 4.0), seed=st.integers(0, 2**16))
def test_softmax_hypothesis(n_hc, n_mc, gain, seed):
    s = _rand(jax.random.PRNGKey(seed), n_hc * n_mc)
    got = hc_softmax(s, n_hc=n_hc, n_mc=n_mc, gain=gain)
    want = ref.hc_softmax_ref(s, n_hc, n_mc, gain)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- plasticity


def _mk_plasticity_inputs(seed, n_in, n_h):
    k = jax.random.split(jax.random.PRNGKey(seed), 5)
    pij = jax.random.uniform(k[0], (n_in, n_h)) * 0.2 + 0.001
    pi = jax.random.uniform(k[1], (n_in,)) * 0.5 + 0.01
    pj = jax.random.uniform(k[2], (n_h,)) * 0.5 + 0.01
    x = jax.nn.softmax(_rand(k[3], n_in))
    y = jax.nn.softmax(_rand(k[4], n_h))
    return pij, pi, pj, x, y


@pytest.mark.parametrize("n_in,n_h", [(16, 16), (288, 128), (64, 256)])
def test_plasticity_matches_ref(n_in, n_h):
    pij, pi, pj, x, y = _mk_plasticity_inputs(8, n_in, n_h)
    got_p, got_w = plasticity(pij, pi, pj, x, y, alpha=1e-2, eps=1e-8)
    want_p, want_w = ref.plasticity_ref(pij, pi, pj, x, y, 1e-2, 1e-8)
    np.testing.assert_allclose(got_p, want_p, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got_w, want_w, rtol=RTOL, atol=ATOL)


def test_plasticity_zero_alpha_keeps_traces():
    pij, pi, pj, x, y = _mk_plasticity_inputs(9, 32, 16)
    got_p, _ = plasticity(pij, pi, pj, x, y, alpha=0.0, eps=1e-8)
    np.testing.assert_allclose(got_p, pij, rtol=1e-6, atol=1e-7)


def test_plasticity_traces_stay_probabilities():
    """After many updates with activities in [0,1], traces remain in (0,1)."""
    pij, pi, pj, x, y = _mk_plasticity_inputs(10, 32, 16)
    p = pij
    for _ in range(50):
        p, _ = plasticity(p, pi, pj, x, y, alpha=0.1, eps=1e-8)
    p = np.asarray(p)
    assert np.all(p > 0.0) and np.all(p < 1.0)


def test_plasticity_weight_sign_semantics():
    """w_ij > 0 iff p_ij > p_i p_j (mutual information sign)."""
    n_in, n_h = 8, 8
    pi = jnp.full((n_in,), 0.5)
    pj = jnp.full((n_h,), 0.5)
    pij = jnp.full((n_in, n_h), 0.25)  # exactly independent
    x = jnp.zeros((n_in,))
    y = jnp.zeros((n_h,))
    _, w = plasticity(pij, pi, pj, x, y, alpha=0.0, eps=1e-8)
    np.testing.assert_allclose(w, np.zeros((n_in, n_h)), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n_in=st.sampled_from([8, 32, 96]), n_h=st.sampled_from([8, 64]),
       alpha=st.floats(1e-4, 0.5), seed=st.integers(0, 2**16))
def test_plasticity_hypothesis(n_in, n_h, alpha, seed):
    pij, pi, pj, x, y = _mk_plasticity_inputs(seed, n_in, n_h)
    got_p, got_w = plasticity(pij, pi, pj, x, y, alpha=alpha, eps=1e-8)
    want_p, want_w = ref.plasticity_ref(pij, pi, pj, x, y, alpha, 1e-8)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(got_w, want_w, rtol=1e-4, atol=1e-4)


# -------------------------------------------------- config-driven kernels


@pytest.mark.parametrize("name", ["tiny", "small", "edge"])
def test_kernels_at_config_shapes(name):
    """Kernels agree with oracle at every AOT'd config's exact shapes."""
    cfg = CONFIGS[name]
    w, x, m, b = _mk_support_inputs(11, cfg.n_in, cfg.n_h)
    got = support(w, x, m, b, tile_in=cfg.resolved_tile_in(),
                  tile_h=cfg.resolved_tile_h())
    np.testing.assert_allclose(got, ref.support_ref(w, x, m, b),
                               rtol=RTOL, atol=ATOL)
    s = ref.support_ref(w, x, m, b)
    got_y = hc_softmax(s, n_hc=cfg.hc_h, n_mc=cfg.mc_h, gain=cfg.gain)
    np.testing.assert_allclose(
        got_y, ref.hc_softmax_ref(s, cfg.hc_h, cfg.mc_h, cfg.gain),
        rtol=RTOL, atol=ATOL)
