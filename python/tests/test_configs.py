"""Config registry invariants (python side; rust mirrors these in
rust/src/config/ tests against the same constants)."""

import pytest

from compile.configs import (CONFIGS, DATASETS, DEFAULT_AOT_CONFIGS, MODES,
                             ModelConfig, _largest_divisor)


def test_paper_table1_shapes():
    """Table 1 of the paper, verbatim."""
    m1, m2, m3 = CONFIGS["model1"], CONFIGS["model2"], CONFIGS["model3"]
    assert (m1.img_side, m1.hc_h, m1.mc_h, m1.n_classes, m1.nact_hi) == \
        (28, 32, 128, 10, 128)
    assert (m2.img_side, m2.hc_h, m2.mc_h, m2.n_classes, m2.nact_hi) == \
        (28, 32, 256, 2, 128)
    assert (m3.img_side, m3.hc_h, m3.mc_h, m3.n_classes, m3.nact_hi) == \
        (64, 32, 128, 2, 128)
    assert DATASETS["model1"] == {"train": 60000, "test": 10000, "epochs": 5}
    assert DATASETS["model2"] == {"train": 4708, "test": 624, "epochs": 20}
    assert DATASETS["model3"] == {"train": 546, "test": 156, "epochs": 100}


@pytest.mark.parametrize("name", list(CONFIGS))
def test_derived_dims(name):
    cfg = CONFIGS[name]
    assert cfg.hc_in == cfg.img_side ** 2
    assert cfg.n_in == cfg.hc_in * cfg.mc_in
    assert cfg.n_h == cfg.hc_h * cfg.mc_h
    assert 0 < cfg.nact_hi <= cfg.hc_in
    assert cfg.n_classes >= 2
    assert 0 < cfg.alpha < 1


@pytest.mark.parametrize("name", list(CONFIGS))
def test_tiles_divide(name):
    cfg = CONFIGS[name]
    assert cfg.n_in % cfg.resolved_tile_in() == 0
    assert cfg.n_h % cfg.resolved_tile_h() == 0


def test_largest_divisor():
    assert _largest_divisor(288, 128) == 96
    assert _largest_divisor(128, 128) == 128
    assert _largest_divisor(7, 4) == 1


def test_default_aot_configs_exist():
    for n in DEFAULT_AOT_CONFIGS:
        assert n in CONFIGS
    assert set(MODES) == {"infer", "train_unsup", "train_sup"}


def test_every_config_has_dataset_spec():
    for n in CONFIGS:
        assert n in DATASETS, n
        d = DATASETS[n]
        assert d["train"] > 0 and d["test"] > 0 and d["epochs"] > 0


def test_frozen_config():
    with pytest.raises(Exception):
        CONFIGS["tiny"].img_side = 10  # frozen dataclass
