"""Synthetic dataset generator: PRNG golden vectors (shared with rust)
and statistical/structural properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datasets


def test_xorshift_golden_vector():
    """Golden values — rust/src/data/rng.rs asserts the same sequence."""
    rng = datasets.XorShift64(42)
    got = [rng.next_u64() for _ in range(4)]
    want = [6255019084209693600, 14430073426741505498,
            14575455857230217846, 17414512882241728735]
    assert got == want, got


def test_xorshift_zero_seed_remapped():
    rng = datasets.XorShift64(0)
    assert rng.state != 0
    assert rng.next_u64() != 0


def test_next_f32_in_unit_interval():
    rng = datasets.XorShift64(7)
    vals = [rng.next_f32() for _ in range(1000)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert 0.4 < float(np.mean(vals)) < 0.6  # roughly uniform


def test_prototypes_deterministic_and_bounded():
    p1 = datasets.class_prototypes(8, 4, seed=1)
    p2 = datasets.class_prototypes(8, 4, seed=1)
    np.testing.assert_array_equal(p1, p2)
    assert p1.shape == (4, 64)
    assert p1.min() >= 0.0 and p1.max() <= 1.0


def test_prototypes_distinct_across_classes():
    p = datasets.class_prototypes(8, 4, seed=2)
    for a in range(4):
        for b in range(a + 1, 4):
            assert not np.allclose(p[a], p[b], atol=1e-3)


def test_generate_shapes_labels_balanced():
    imgs, labels = datasets.generate(8, 4, 400, seed=3)
    assert imgs.shape == (400, 64) and labels.shape == (400,)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    counts = np.bincount(labels, minlength=4)
    assert counts.min() > 50  # roughly balanced random classes


def test_generate_deterministic():
    a = datasets.generate(8, 2, 32, seed=9)
    b = datasets.generate(8, 2, 32, seed=9)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_generate_classes_separable():
    """Nearest-prototype classification of generated data ~ near-perfect:
    the structure BCPNN is expected to discover exists."""
    side, ncls = 8, 4
    imgs, labels = datasets.generate(side, ncls, 200, seed=4, noise=0.1)
    protos = datasets.class_prototypes(side, ncls, seed=4)
    d = ((imgs[:, None, :] - protos[None, :, :]) ** 2).sum(-1)
    pred = np.argmin(d, axis=1)
    acc = float(np.mean(pred == labels))
    assert acc > 0.9, acc


@settings(max_examples=10, deadline=None)
@given(side=st.sampled_from([4, 8, 12]), ncls=st.integers(2, 6),
       seed=st.integers(0, 2**32 - 1))
def test_generate_hypothesis(side, ncls, seed):
    imgs, labels = datasets.generate(side, ncls, 16, seed=seed)
    assert imgs.shape == (16, side * side)
    assert np.all((labels >= 0) & (labels < ncls))
    assert np.all(np.isfinite(imgs))
