"""Pallas kernel: masked support mat-vec  s = b + (w*m)^T x.

This is the activation hot-spot of the paper's accelerator (the
input->hidden projection stream). The FPGA version streams the weight
matrix HBM->FIFO in 64-float merged packets (Fig. 4); here the analogous
schedule is expressed with BlockSpec: the (n_in, n_h) weight and mask
arrays are tiled into (TILE_IN, TILE_H) VMEM blocks — the "packet" — and
partial supports are accumulated into the output block across the
reduction grid dimension.

Grid layout: (n_h/TILE_H, n_in/TILE_IN); the inner (last) grid axis is
the reduction over input tiles so the output block stays resident in
VMEM while partials accumulate (revisited-output accumulation pattern).

interpret=True always: CPU PJRT cannot run Mosaic custom-calls; the
interpret path lowers to plain HLO so the AOT artifact is portable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _support_kernel(x_ref, w_ref, m_ref, b_ref, o_ref):
    """One (TILE_IN, TILE_H) packet: accumulate partial masked mat-vec."""
    ri = pl.program_id(1)  # reduction step over input tiles

    # First reduction step seeds the accumulator with the bias.
    @pl.when(ri == 0)
    def _():
        o_ref[...] = b_ref[...]

    x = x_ref[...]                      # (TILE_IN,)
    wm = w_ref[...] * m_ref[...]        # (TILE_IN, TILE_H) masked packet
    # Partial support for this packet; accumulate into the output block.
    o_ref[...] += jnp.dot(x, wm)


@functools.partial(jax.jit, static_argnames=("tile_in", "tile_h"))
def support(w, x, m, b, *, tile_in=0, tile_h=0):
    """Masked support mat-vec via Pallas.

    Args:
      w: (n_in, n_h) f32 weights.
      x: (n_in,) f32 input activity.
      m: (n_in, n_h) f32 0/1 unit mask.
      b: (n_h,) f32 bias.
      tile_in/tile_h: packet dims; must divide n_in / n_h (0 = auto).
    Returns: (n_h,) f32 support.
    """
    n_in, n_h = w.shape
    tile_in = tile_in or _auto_tile(n_in)
    tile_h = tile_h or _auto_tile(n_h)
    assert n_in % tile_in == 0 and n_h % tile_h == 0, (
        f"tiles ({tile_in},{tile_h}) must divide ({n_in},{n_h})"
    )
    grid = (n_h // tile_h, n_in // tile_in)
    return pl.pallas_call(
        _support_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_in,), lambda h, i: (i,)),            # x
            pl.BlockSpec((tile_in, tile_h), lambda h, i: (i, h)),   # w
            pl.BlockSpec((tile_in, tile_h), lambda h, i: (i, h)),   # m
            pl.BlockSpec((tile_h,), lambda h, i: (h,)),             # b
        ],
        out_specs=pl.BlockSpec((tile_h,), lambda h, i: (h,)),
        out_shape=jax.ShapeDtypeStruct((n_h,), jnp.float32),
        interpret=True,
    )(x, w, m, b)


def _auto_tile(n):
    # Full-array tile: fastest under interpret=True (grid emulation
    # dominates otherwise); pass explicit tiles for a real-TPU build.
    return n
