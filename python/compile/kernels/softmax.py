"""Pallas kernel: per-hypercolumn softmax.

The paper's divisive-normalization stage: minicolumns within a
hypercolumn compete via softmax, producing a probability distribution per
HC. On the FPGA this is the stage that "requires waiting until all
relevant data arrives" (the reduction barrier that sizes the FIFOs); in
Pallas the analogous structure is a grid over hypercolumns with each
block holding one HC's full minicolumn vector in VMEM — block-local
max/exp/sum with no cross-block traffic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hc_softmax_kernel(gain, s_ref, o_ref):
    """One hypercolumn block: numerically-stable softmax over its MCs."""
    s = gain * s_ref[...]                     # (hc_block, n_mc)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("n_hc", "n_mc", "gain", "hc_block"))
def hc_softmax(s, *, n_hc, n_mc, gain=1.0, hc_block=0):
    """Softmax within each hypercolumn.

    Args:
      s: (n_hc * n_mc,) f32 support values.
      n_hc: number of hypercolumns.
      n_mc: minicolumns per hypercolumn.
      gain: softmax gain G (support scaling).
      hc_block: hypercolumns per grid block (0 = auto divisor <= 8).
    Returns: (n_hc * n_mc,) f32 activity; each HC slice sums to 1.
    """
    assert s.shape == (n_hc * n_mc,), (s.shape, n_hc, n_mc)
    hc_block = hc_block or _auto_block(n_hc)
    grid = (n_hc // hc_block,)
    out = pl.pallas_call(
        functools.partial(_hc_softmax_kernel, float(gain)),
        grid=grid,
        in_specs=[pl.BlockSpec((hc_block, n_mc), lambda h: (h, 0))],
        out_specs=pl.BlockSpec((hc_block, n_mc), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((n_hc, n_mc), jnp.float32),
        interpret=True,
    )(s.reshape(n_hc, n_mc))
    return out.reshape(-1)


def _auto_block(n_hc, cap=64):
    for d in range(min(cap, n_hc), 0, -1):
        if n_hc % d == 0:
            return d
    return 1
