"""Pallas kernel: fused Hebbian-Bayesian plasticity update.

The paper's synaptic-plasticity hot-spot: per training image the
(n_in, n_h) joint-probability trace is EMA-updated with the outer product
of pre/post activity and the Bayesian log-weights are recomputed from the
traces. The FPGA fuses these into a single streamed pass over the joint
arrays (read p_ij packet -> update -> write p_ij' and w packets, one HBM
round trip); this kernel expresses the same fusion: one grid pass over
(TILE_IN, TILE_H) blocks producing both outputs, so the joint trace is
touched exactly once per image.

The cheap O(n) marginal-trace EMAs (p_i, p_j) stay in L2 jnp; the kernel
receives the already-updated marginals, mirroring the FPGA pipeline where
the small population arrays live on-chip while the joint arrays stream
through the 4-way partitioned HBM channels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plasticity_kernel(alpha, eps, pij_ref, pi_ref, pj_ref, x_ref, y_ref,
                       pij_out_ref, w_out_ref):
    """One (TILE_IN, TILE_H) packet: EMA joint update + log-weight map."""
    x = x_ref[...]          # (TILE_IN,)
    y = y_ref[...]          # (TILE_H,)
    pij = pij_ref[...]      # (TILE_IN, TILE_H)
    pij_new = (1.0 - alpha) * pij + alpha * (x[:, None] * y[None, :])
    pij_out_ref[...] = pij_new
    pi = pi_ref[...]        # (TILE_IN,) updated marginals
    pj = pj_ref[...]        # (TILE_H,)
    w_out_ref[...] = jnp.log(
        (pij_new + eps * eps) / ((pi[:, None] + eps) * (pj[None, :] + eps))
    )


@functools.partial(
    jax.jit, static_argnames=("alpha", "eps", "tile_in", "tile_h")
)
def plasticity(pij, pi_new, pj_new, x, y, *, alpha, eps,
               tile_in=0, tile_h=0):
    """Fused joint-trace EMA + Bayesian weight recompute via Pallas.

    Args:
      pij: (n_in, n_h) f32 joint probability trace.
      pi_new: (n_in,) f32 updated presynaptic marginal trace.
      pj_new: (n_h,) f32 updated postsynaptic marginal trace.
      x: (n_in,) f32 presynaptic activity.
      y: (n_h,) f32 postsynaptic activity.
      alpha: EMA learning rate (static).
      eps: probability floor (static).
    Returns: (pij_new, w), both (n_in, n_h) f32.
    """
    n_in, n_h = pij.shape
    tile_in = tile_in or _auto_tile(n_in)
    tile_h = tile_h or _auto_tile(n_h)
    assert n_in % tile_in == 0 and n_h % tile_h == 0, (
        f"tiles ({tile_in},{tile_h}) must divide ({n_in},{n_h})"
    )
    grid = (n_in // tile_in, n_h // tile_h)
    vec_in = pl.BlockSpec((tile_in,), lambda i, h: (i,))
    vec_h = pl.BlockSpec((tile_h,), lambda i, h: (h,))
    mat = pl.BlockSpec((tile_in, tile_h), lambda i, h: (i, h))
    return pl.pallas_call(
        functools.partial(
            _plasticity_kernel, float(alpha), float(eps)
        ),
        grid=grid,
        in_specs=[mat, vec_in, vec_h, vec_in, vec_h],
        out_specs=[mat, mat],
        out_shape=[
            jax.ShapeDtypeStruct((n_in, n_h), jnp.float32),
            jax.ShapeDtypeStruct((n_in, n_h), jnp.float32),
        ],
        interpret=True,
    )(pij, pi_new, pj_new, x, y)


def _auto_tile(n):
    # Full-array tile: fastest under interpret=True (grid emulation
    # dominates otherwise); pass explicit tiles for a real-TPU build.
    return n
