"""L1 Pallas kernels for the BCPNN hot-spots + pure-jnp reference.

Kernels (all interpret=True so they lower to portable HLO):
  - support.support       masked support mat-vec  s = b + (w*m)^T x
  - softmax.hc_softmax    per-hypercolumn softmax (divisive normalization)
  - plasticity.plasticity fused joint-trace EMA + Bayesian weight map

``ref`` holds the jnp oracles used by pytest and by the A/B model build.
"""

from . import ref  # noqa: F401
from .plasticity import plasticity  # noqa: F401
from .softmax import hc_softmax  # noqa: F401
from .support import support  # noqa: F401
