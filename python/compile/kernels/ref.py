"""Pure-jnp correctness oracle for the Pallas kernels.

Every Pallas kernel in this package has an exact reference here, written
with plain jax.numpy ops only. pytest (python/tests/test_kernels.py)
asserts allclose between kernel and reference across shape/dtype sweeps;
the L2 model can also be built entirely on these functions
(``model.build_steps(cfg, use_pallas=False)``) which is how we A/B the
kernels end-to-end.

Math (rate-based feedforward BCPNN, Ravichandran et al. 2024):

  support   s_j  = b_j + sum_i m_ij w_ij x_i
  activity  y    = softmax_per_hypercolumn(G * s)
  traces    p_i  <- (1-a) p_i  + a x_i
            p_j  <- (1-a) p_j  + a y_j
            p_ij <- (1-a) p_ij + a x_i y_j
  weights   w_ij = log((p_ij + eps^2) / ((p_i + eps)(p_j + eps)))
  bias      b_j  = log(p_j + eps)
"""

import jax.numpy as jnp


def support_ref(w, x, m, b):
    """Masked support mat-vec.

    Args:
      w: (n_in, n_h) f32 weights.
      x: (n_in,) f32 presynaptic activity.
      m: (n_in, n_h) f32 0/1 unit-level connection mask.
      b: (n_h,) f32 bias.
    Returns: (n_h,) f32 support values.
    """
    return b + (w * m).T @ x


def hc_softmax_ref(s, n_hc, n_mc, gain=1.0):
    """Softmax within each hypercolumn.

    Args:
      s: (n_hc * n_mc,) f32 support.
    Returns: (n_hc * n_mc,) f32 activity; each HC's slice sums to 1.
    """
    s2 = (gain * s).reshape(n_hc, n_mc)
    s2 = s2 - jnp.max(s2, axis=1, keepdims=True)
    e = jnp.exp(s2)
    y = e / jnp.sum(e, axis=1, keepdims=True)
    return y.reshape(-1)


def plasticity_ref(pij, pi_new, pj_new, x, y, alpha, eps):
    """Fused joint-trace EMA update + Bayesian weight recompute.

    ``pi_new``/``pj_new`` are the *already updated* marginal traces (the
    cheap vector EMAs run in L2); the kernel fuses the expensive
    (n_in, n_h) part: the joint trace update and the log-weight map.

    Args:
      pij: (n_in, n_h) f32 joint probability trace.
      pi_new: (n_in,) f32 updated presynaptic trace.
      pj_new: (n_h,) f32 updated postsynaptic trace.
      x: (n_in,) f32 presynaptic activity.
      y: (n_h,) f32 postsynaptic activity.
    Returns: (pij_new, w) both (n_in, n_h) f32.
    """
    pij_new = (1.0 - alpha) * pij + alpha * jnp.outer(x, y)
    w = jnp.log(
        (pij_new + eps * eps)
        / ((pi_new[:, None] + eps) * (pj_new[None, :] + eps))
    )
    return pij_new, w


def marginal_update_ref(p, v, alpha):
    """EMA update of a marginal probability trace (vector)."""
    return (1.0 - alpha) * p + alpha * v


def bias_ref(pj, eps):
    """Bias from the postsynaptic trace: b_j = log(p_j + eps)."""
    return jnp.log(pj + eps)
