"""Synthetic structured datasets (build-time python mirror).

The paper evaluates MNIST / PneumoniaMNIST / BreastMNIST; neither is
available offline here, so (per the substitution rule) we generate
class-conditional structured images with the same shapes and sizes. The
generator produces per-class "prototype" blob/stroke patterns plus pixel
noise and random intensity jitter — enough class structure for BCPNN's
unsupervised representation learning to separate classes well above
chance, while exercising exactly the tensor shapes of Table 1.

The Rust side (`rust/src/data/`) implements the same generator with the
same xorshift PRNG so python tests and rust runs see identical data for
identical seeds (cross-checked in python/tests/test_datasets.py against
vectors in rust tests).
"""

import numpy as np


_MASK64 = (1 << 64) - 1


class XorShift64:
    """xorshift64* PRNG — tiny, portable, identical in rust/src/data/rng.rs."""

    def __init__(self, seed: int):
        self.state = (seed & _MASK64) or 0x9E3779B97F4A7C15

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x ^= (x << 25) & _MASK64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & _MASK64

    def next_f32(self) -> float:
        """Uniform in [0, 1) with 24 bits of mantissa (matches rust)."""
        return (self.next_u64() >> 40) / float(1 << 24)

    def next_range(self, n: int) -> int:
        return self.next_u64() % n


def class_prototypes(side: int, n_classes: int, seed: int) -> np.ndarray:
    """Per-class prototype images: a few gaussian blobs per class.

    Returns (n_classes, side*side) f32 in [0,1].
    """
    rng = XorShift64(seed)
    protos = np.zeros((n_classes, side, side), np.float32)
    n_blobs = 3
    for c in range(n_classes):
        for _ in range(n_blobs):
            cx = rng.next_f32() * side
            cy = rng.next_f32() * side
            sigma = 1.0 + rng.next_f32() * (side / 6.0)
            amp = 0.5 + rng.next_f32() * 0.5
            ys, xs = np.mgrid[0:side, 0:side].astype(np.float32)
            d2 = (xs - cx) ** 2 + (ys - cy) ** 2
            protos[c] += amp * np.exp(-d2 / (2.0 * sigma * sigma))
    protos = np.clip(protos, 0.0, 1.0)
    return protos.reshape(n_classes, side * side)


def generate(side: int, n_classes: int, n: int, seed: int,
             noise: float = 0.15):
    """Generate n labelled images.

    Each image = class prototype * intensity jitter + uniform pixel noise,
    clipped to [0,1]. Labels cycle deterministically (balanced classes)
    with order shuffled by the PRNG — same procedure as rust.

    Returns (images (n, side*side) f32, labels (n,) i32).
    """
    protos = class_prototypes(side, n_classes, seed)
    rng = XorShift64(seed ^ 0xDEADBEEF)
    imgs = np.zeros((n, side * side), np.float32)
    labels = np.zeros((n,), np.int32)
    for i in range(n):
        c = rng.next_range(n_classes)
        labels[i] = c
        jitter = 0.7 + 0.3 * rng.next_f32()
        img = protos[c] * jitter
        for p in range(img.shape[0]):
            img[p] += noise * (rng.next_f32() - 0.5)
        imgs[i] = np.clip(img, 0.0, 1.0)
    return imgs, labels
