"""Model configurations for the BCPNN accelerator reproduction.

Mirrors Table 1 of the paper plus reduced configs used for measured
(interpret-mode Pallas) execution. The Rust side carries the same set in
`rust/src/config/`; the two must stay in sync (checked by
python/tests/test_configs.py against configs/models.toml).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """One BCPNN network configuration (paper Table 1 row or reduced).

    Layout conventions (shared with ref.py / kernels / rust):
      - input layer:  ``hc_in`` hypercolumns x ``mc_in`` minicolumns
        (one HC per pixel, mc_in=2 intensity coding [v, 1-v]);
        ``n_in = hc_in * mc_in`` units.
      - hidden layer: ``hc_h`` x ``mc_h``; ``n_h = hc_h * mc_h`` units.
      - output layer: 1 hypercolumn x ``n_classes`` minicolumns.
      - input->hidden weights / joint traces: shape ``(n_in, n_h)``.
      - structural-plasticity mask: ``(hc_in, hc_h)`` 0/1, ``nact_hi``
        active input HCs per hidden HC.
    """

    name: str
    img_side: int          # square input image side (hc_in = img_side**2)
    hc_h: int              # hidden hypercolumns
    mc_h: int              # hidden minicolumns per HC
    n_classes: int
    nact_hi: int           # active input HCs per hidden HC (sparsity)
    alpha: float = 1e-2    # EMA learning time constant for p-traces
    batch: int = 32        # images per AOT artifact invocation (scan len)
    mc_in: int = 2         # minicolumns per input HC (intensity coding)
    eps: float = 1e-8      # probability floor inside log()
    gain: float = 1.0      # softmax gain on support values
    # Tile sizes for the Pallas kernels (the "HBM packet" analogue).
    #
    # 0 = auto. Auto resolves to the FULL array dimension: under
    # interpret=True (the only executable path on CPU PJRT) every grid
    # step is emulated with dynamic slices, so grid=1 is fastest — the
    # §Perf sweep measured 7-80x vs 128-wide tiles (EXPERIMENTS.md).
    # For a real-TPU build set explicit tiles that fit VMEM (e.g.
    # 256x512: 3 f32 buffers = 1.5 MB << 16 MB; DESIGN.md §Hardware-
    # Adaptation) — the kernels honour any divisor.
    tile_in: int = 0       # 0 = auto (full n_in on the interpret path)
    tile_h: int = 0        # 0 = auto (full n_h on the interpret path)

    @property
    def hc_in(self) -> int:
        return self.img_side * self.img_side

    @property
    def n_in(self) -> int:
        return self.hc_in * self.mc_in

    @property
    def n_h(self) -> int:
        return self.hc_h * self.mc_h

    @property
    def n_out(self) -> int:
        return self.n_classes

    def resolved_tile_in(self) -> int:
        return self.tile_in or self.n_in

    def resolved_tile_h(self) -> int:
        return self.tile_h or self.n_h


def _largest_divisor(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


# ---------------------------------------------------------------------------
# The configuration registry.
#
# tiny / small / edge are reduced shapes for measured interpret-mode runs
# (tests, examples, e2e benches). model1/2/3 are the paper's Table 1 shapes,
# used by the analytical paths (resource estimator, roofline, timing model)
# and AOT-lowerable with --full.
# ---------------------------------------------------------------------------

CONFIGS = {
    # Reduced, measured configs -------------------------------------------
    "tiny": ModelConfig(
        name="tiny", img_side=8, hc_h=4, mc_h=16, n_classes=4,
        nact_hi=32, alpha=2e-2, batch=16,
    ),
    "small": ModelConfig(
        name="small", img_side=12, hc_h=8, mc_h=16, n_classes=10,
        nact_hi=64, alpha=1e-2, batch=32,
    ),
    # edge alpha=5e-2: the 2-class readout needs a short trace time
    # constant at this dataset size (1e-2 stalls at chance — see
    # EXPERIMENTS.md §E2E notes).
    "edge": ModelConfig(
        name="edge", img_side=16, hc_h=8, mc_h=32, n_classes=2,
        nact_hi=96, alpha=5e-2, batch=32,
    ),
    # Paper Table 1 shapes --------------------------------------------------
    "model1": ModelConfig(  # MNIST
        name="model1", img_side=28, hc_h=32, mc_h=128, n_classes=10,
        nact_hi=128, alpha=1e-3, batch=32,
    ),
    "model2": ModelConfig(  # PneumoniaMNIST
        name="model2", img_side=28, hc_h=32, mc_h=256, n_classes=2,
        nact_hi=128, alpha=1e-3, batch=32,
    ),
    "model3": ModelConfig(  # BreastMNIST
        name="model3", img_side=64, hc_h=32, mc_h=128, n_classes=2,
        nact_hi=128, alpha=1e-3, batch=32,
    ),
}

# Dataset sizes per paper Table 1 (train, test, unsupervised epochs).
DATASETS = {
    "model1": {"train": 60000, "test": 10000, "epochs": 5},
    "model2": {"train": 4708, "test": 624, "epochs": 20},
    "model3": {"train": 546, "test": 156, "epochs": 100},
    "tiny": {"train": 256, "test": 64, "epochs": 3},
    "small": {"train": 512, "test": 128, "epochs": 3},
    "edge": {"train": 512, "test": 128, "epochs": 5},
}

MODES = ("infer", "train_unsup", "train_sup")

DEFAULT_AOT_CONFIGS = ("tiny", "small", "edge")
FULL_AOT_CONFIGS = tuple(CONFIGS)
