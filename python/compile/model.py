"""L2: the feedforward BCPNN model (build-time JAX, calls kernels.*).

The full network of the paper: input population (one hypercolumn per
pixel, 2 minicolumns of intensity coding), hidden population (hc_h x
mc_h), output population (1 HC x n_classes). Two plastic projections:

  input  -> hidden : unsupervised Hebbian-Bayesian (+ structural mask)
  hidden -> output : supervised (labels as postsynaptic one-hot)

Everything here is traced once by aot.py and lowered to HLO text; at run
time the Rust coordinator executes the artifacts via PJRT and performs
the host-side structural-plasticity step between calls (as in the paper:
"the structural plasticity ... happens in the host").

Three artifact entry points per model config, each scanning a fixed-size
batch (the paper's streaming semantics: strictly online, one image at a
time — the scan only amortizes dispatch):

  infer        (wij, bj, who, bk, mask_hc, imgs)          -> probs
  train_unsup  (pi, pj, pij, mask_hc, imgs)               -> traces', w', b'
  train_sup    (wij, bj, mask_hc, qi, qk, qik, imgs, lbl) -> traces', who', bk'

All params are explicit positional arrays (no pytrees at the boundary)
so the Rust side can marshal Literals by position; the exact signatures
are recorded in artifacts/manifest.json by aot.py.
"""

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig
from .kernels import ref


def encode_image(img, cfg: ModelConfig):
    """Intensity coding: pixel v -> input HC activity [v, 1-v].

    Args:
      img: (hc_in,) f32 in [0,1].
    Returns: (n_in,) f32; each input HC's minicolumn pair sums to 1.
    """
    assert cfg.mc_in == 2, "intensity coding requires mc_in == 2"
    v = jnp.clip(img, 0.0, 1.0)
    return jnp.stack([v, 1.0 - v], axis=-1).reshape(-1)


def expand_mask(mask_hc, cfg: ModelConfig):
    """Expand the (hc_in, hc_h) HC-level mask to unit level (n_in, n_h)."""
    m = jnp.repeat(mask_hc, cfg.mc_in, axis=0)
    return jnp.repeat(m, cfg.mc_h, axis=1)


def init_params(cfg: ModelConfig, seed: int = 0, jitter: float = 0.2):
    """Initial traces (uniform independence + symmetry-breaking jitter)
    and the weights/biases derived from them.

    ``jitter`` multiplies the joint trace by U(1-j, 1+j): with exactly
    uniform traces every minicolumn of a hidden hypercolumn is identical
    and Hebbian learning can never differentiate them (all MCs share the
    receptive field and the softmax ties); the BCPNN literature breaks
    the tie with random initial weights/noise — we jitter p_ij, which is
    equivalent and keeps traces interpretable as probabilities. The Rust
    side mirrors this in ``bcpnn::params`` with the shared xorshift PRNG.
    """
    n_in, n_h, n_out = cfg.n_in, cfg.n_h, cfg.n_out
    pi = jnp.full((n_in,), 1.0 / cfg.mc_in, jnp.float32)
    pj = jnp.full((n_h,), 1.0 / cfg.mc_h, jnp.float32)
    pij = jnp.full((n_in, n_h), 1.0 / (cfg.mc_in * cfg.mc_h), jnp.float32)
    if jitter > 0.0:
        u = jax.random.uniform(jax.random.PRNGKey(seed), (n_in, n_h),
                               minval=1.0 - jitter, maxval=1.0 + jitter)
        pij = pij * u
    qi = jnp.full((n_h,), 1.0 / cfg.mc_h, jnp.float32)
    qk = jnp.full((n_out,), 1.0 / n_out, jnp.float32)
    qik = jnp.full((n_h, n_out), 1.0 / (cfg.mc_h * n_out), jnp.float32)
    eps = cfg.eps
    wij = jnp.log((pij + eps * eps) / ((pi[:, None] + eps) * (pj[None, :] + eps)))
    bj = jnp.log(pj + eps)
    who = jnp.log((qik + eps * eps) / ((qi[:, None] + eps) * (qk[None, :] + eps)))
    bk = jnp.log(qk + eps)
    return {
        "pi": pi, "pj": pj, "pij": pij, "wij": wij, "bj": bj,
        "qi": qi, "qk": qk, "qik": qik, "who": who, "bk": bk,
    }


def init_mask(cfg: ModelConfig, seed: int = 0):
    """Random structural mask: nact_hi active input HCs per hidden HC."""
    key = jax.random.PRNGKey(seed)
    cols = []
    for h in range(cfg.hc_h):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, cfg.hc_in)
        col = jnp.zeros((cfg.hc_in,), jnp.float32).at[perm[: cfg.nact_hi]].set(1.0)
        cols.append(col)
    return jnp.stack(cols, axis=1)  # (hc_in, hc_h)


# ---------------------------------------------------------------------------
# Single-image steps (the streaming element the FPGA pipeline processes).
# ---------------------------------------------------------------------------


def build_steps(cfg: ModelConfig, use_pallas: bool = True):
    """Build the per-image step functions for a config.

    use_pallas=False swaps every kernel for its jnp oracle — the A/B used
    by pytest to validate the Pallas path end-to-end.
    """
    ti, th = cfg.resolved_tile_in(), cfg.resolved_tile_h()

    def _support(w, x, m, b):
        if use_pallas:
            return kernels.support(w, x, m, b, tile_in=ti, tile_h=th)
        return ref.support_ref(w, x, m, b)

    def _hidden_softmax(s):
        if use_pallas:
            return kernels.hc_softmax(
                s, n_hc=cfg.hc_h, n_mc=cfg.mc_h, gain=cfg.gain
            )
        return ref.hc_softmax_ref(s, cfg.hc_h, cfg.mc_h, cfg.gain)

    def _plasticity(pij, pi_new, pj_new, x, y):
        if use_pallas:
            return kernels.plasticity(
                pij, pi_new, pj_new, x, y,
                alpha=cfg.alpha, eps=cfg.eps, tile_in=ti, tile_h=th,
            )
        return ref.plasticity_ref(pij, pi_new, pj_new, x, y, cfg.alpha, cfg.eps)

    def hidden_activity(wij, bj, mask_hc, img):
        """Input encoding -> masked support -> per-HC softmax."""
        x = encode_image(img, cfg)
        m = expand_mask(mask_hc, cfg)
        s = _support(wij, x, m, b=bj)
        return x, _hidden_softmax(s)

    def output_activity(who, bk, y):
        """hidden->output projection: single output HC softmax (no mask)."""
        sk = bk + who.T @ y
        sk = sk - jnp.max(sk)
        e = jnp.exp(sk)
        return e / jnp.sum(e)

    def infer_step(wij, bj, who, bk, mask_hc, img):
        _, y = hidden_activity(wij, bj, mask_hc, img)
        return output_activity(who, bk, y)

    def train_unsup_step(pi, pj, pij, wij, bj, mask_hc, img):
        """One online Hebbian-Bayesian update of the input->hidden projection."""
        x, y = hidden_activity(wij, bj, mask_hc, img)
        pi_new = ref.marginal_update_ref(pi, x, cfg.alpha)
        pj_new = ref.marginal_update_ref(pj, y, cfg.alpha)
        pij_new, wij_new = _plasticity(pij, pi_new, pj_new, x, y)
        bj_new = ref.bias_ref(pj_new, cfg.eps)
        return pi_new, pj_new, pij_new, wij_new, bj_new

    def train_sup_step(wij, bj, mask_hc, qi, qk, qik, who, bk, img, label):
        """Supervised hidden->output update: label one-hot as post activity."""
        _, y = hidden_activity(wij, bj, mask_hc, img)
        t = jax.nn.one_hot(label, cfg.n_out, dtype=jnp.float32)
        qi_new = ref.marginal_update_ref(qi, y, cfg.alpha)
        qk_new = ref.marginal_update_ref(qk, t, cfg.alpha)
        qik_new = (1.0 - cfg.alpha) * qik + cfg.alpha * jnp.outer(y, t)
        eps = cfg.eps
        who_new = jnp.log(
            (qik_new + eps * eps)
            / ((qi_new[:, None] + eps) * (qk_new[None, :] + eps))
        )
        bk_new = ref.bias_ref(qk_new, eps)
        return qi_new, qk_new, qik_new, who_new, bk_new

    return {
        "hidden_activity": hidden_activity,
        "output_activity": output_activity,
        "infer_step": infer_step,
        "train_unsup_step": train_unsup_step,
        "train_sup_step": train_sup_step,
    }


# ---------------------------------------------------------------------------
# Batched artifact entry points (lax.scan over the fixed batch dimension).
# ---------------------------------------------------------------------------


def build_infer(cfg: ModelConfig, use_pallas: bool = True):
    steps = build_steps(cfg, use_pallas)

    def infer(wij, bj, who, bk, mask_hc, imgs):
        """imgs: (B, hc_in) -> probs: (B, n_out)."""
        def body(carry, img):
            probs = steps["infer_step"](wij, bj, who, bk, mask_hc, img)
            return carry, probs

        _, probs = jax.lax.scan(body, 0, imgs)
        return (probs,)

    return infer


def build_train_unsup(cfg: ModelConfig, use_pallas: bool = True):
    steps = build_steps(cfg, use_pallas)
    eps = cfg.eps

    def train_unsup(pi, pj, pij, mask_hc, imgs):
        """Online unsupervised pass over a batch; returns updated traces
        and the weights/bias derived from the final traces."""
        wij0 = jnp.log(
            (pij + eps * eps) / ((pi[:, None] + eps) * (pj[None, :] + eps))
        )
        bj0 = jnp.log(pj + eps)

        def body(carry, img):
            pi_c, pj_c, pij_c, wij_c, bj_c = carry
            out = steps["train_unsup_step"](pi_c, pj_c, pij_c, wij_c, bj_c,
                                            mask_hc, img)
            return out, 0

        (pi_n, pj_n, pij_n, wij_n, bj_n), _ = jax.lax.scan(
            body, (pi, pj, pij, wij0, bj0), imgs
        )
        return pi_n, pj_n, pij_n, wij_n, bj_n

    return train_unsup


def build_train_sup(cfg: ModelConfig, use_pallas: bool = True):
    steps = build_steps(cfg, use_pallas)

    def train_sup(wij, bj, mask_hc, qi, qk, qik, who, bk, imgs, labels):
        """Supervised pass (input->hidden frozen): update output projection."""
        def body(carry, xs):
            qi_c, qk_c, qik_c, who_c, bk_c = carry
            img, label = xs
            out = steps["train_sup_step"](wij, bj, mask_hc, qi_c, qk_c,
                                          qik_c, who_c, bk_c, img, label)
            return out, 0

        (qi_n, qk_n, qik_n, who_n, bk_n), _ = jax.lax.scan(
            body, (qi, qk, qik, who, bk), (imgs, labels)
        )
        return qi_n, qk_n, qik_n, who_n, bk_n

    return train_sup


def example_args(cfg: ModelConfig, mode: str):
    """ShapeDtypeStructs for jax.jit(...).lower() per artifact mode."""
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    n_in, n_h, n_out, b = cfg.n_in, cfg.n_h, cfg.n_out, cfg.batch
    mask = sds((cfg.hc_in, cfg.hc_h), f32)
    imgs = sds((b, cfg.hc_in), f32)
    if mode == "infer":
        return (sds((n_in, n_h), f32), sds((n_h,), f32),
                sds((n_h, n_out), f32), sds((n_out,), f32), mask, imgs)
    if mode == "train_unsup":
        return (sds((n_in,), f32), sds((n_h,), f32), sds((n_in, n_h), f32),
                mask, imgs)
    if mode == "train_sup":
        return (sds((n_in, n_h), f32), sds((n_h,), f32), mask,
                sds((n_h,), f32), sds((n_out,), f32), sds((n_h, n_out), f32),
                sds((n_h, n_out), f32), sds((n_out,), f32),
                imgs, sds((b,), jnp.int32))
    raise ValueError(f"unknown mode {mode!r}")


def build_fn(cfg: ModelConfig, mode: str, use_pallas: bool = True):
    if mode == "infer":
        return build_infer(cfg, use_pallas)
    if mode == "train_unsup":
        return build_train_unsup(cfg, use_pallas)
    if mode == "train_sup":
        return build_train_sup(cfg, use_pallas)
    raise ValueError(f"unknown mode {mode!r}")
