"""AOT lowering: JAX/Pallas BCPNN -> HLO text artifacts + manifest.

Emits HLO *text* (NOT .serialize()): jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

For each (config x mode) this writes ``artifacts/<cfg>_<mode>.hlo.txt``
and records the exact positional input/output signature in
``artifacts/manifest.json`` — the Rust runtime marshals Literals strictly
by that manifest, so python and rust can never drift silently.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--full]
    python -m compile.aot --configs tiny small --modes infer
"""

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, DATASETS, DEFAULT_AOT_CONFIGS, MODES, ModelConfig
from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_INPUT_NAMES = {
    "infer": ("wij", "bj", "who", "bk", "mask_hc", "imgs"),
    "train_unsup": ("pi", "pj", "pij", "mask_hc", "imgs"),
    "train_sup": ("wij", "bj", "mask_hc", "qi", "qk", "qik", "who", "bk",
                  "imgs", "labels"),
}

_OUTPUT_NAMES = {
    "infer": ("probs",),
    "train_unsup": ("pi", "pj", "pij", "wij", "bj"),
    "train_sup": ("qi", "qk", "qik", "who", "bk"),
}


def _sig(args):
    return [
        {"shape": list(a.shape), "dtype": a.dtype.name} for a in args
    ]


def lower_artifact(cfg: ModelConfig, mode: str):
    """Lower one (config, mode) pair; returns (hlo_text, manifest_entry)."""
    fn = model.build_fn(cfg, mode, use_pallas=True)
    args = model.example_args(cfg, mode)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    out_shapes = [
        {"shape": list(s.shape), "dtype": s.dtype.name}
        for s in jax.eval_shape(fn, *args)
    ]
    entry = {
        "mode": mode,
        "config": {
            "name": cfg.name, "img_side": cfg.img_side, "hc_in": cfg.hc_in,
            "mc_in": cfg.mc_in, "hc_h": cfg.hc_h, "mc_h": cfg.mc_h,
            "n_in": cfg.n_in, "n_h": cfg.n_h, "n_classes": cfg.n_classes,
            "nact_hi": cfg.nact_hi, "alpha": cfg.alpha, "eps": cfg.eps,
            "gain": cfg.gain, "batch": cfg.batch,
            "tile_in": cfg.resolved_tile_in(), "tile_h": cfg.resolved_tile_h(),
        },
        "dataset": DATASETS.get(cfg.name, {}),
        "inputs": [
            {"name": n, **s}
            for n, s in zip(_INPUT_NAMES[mode], _sig(args))
        ],
        "outputs": [
            {"name": n, **s}
            for n, s in zip(_OUTPUT_NAMES[mode], out_shapes)
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="config names (default: tiny small edge)")
    ap.add_argument("--modes", nargs="*", default=list(MODES))
    ap.add_argument("--full", action="store_true",
                    help="also lower the paper-shape models 1-3")
    args = ap.parse_args()

    names = list(args.configs or DEFAULT_AOT_CONFIGS)
    if args.full:
        names += [n for n in ("model1", "model2", "model3") if n not in names]

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    manifest = {"artifacts": {}}
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())

    for name in names:
        cfg = CONFIGS[name]
        for mode in args.modes:
            key = f"{name}_{mode}"
            text, entry = lower_artifact(cfg, mode)
            entry["file"] = f"{key}.hlo.txt"
            (out_dir / entry["file"]).write_text(text)
            manifest["artifacts"][key] = entry
            print(f"wrote {key}: {len(text)} chars "
                  f"({len(entry['inputs'])} in / {len(entry['outputs'])} out)")

    manifest_path.write_text(json.dumps(manifest, indent=2))
    print(f"manifest: {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
