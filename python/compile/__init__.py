"""Build-time python package: L1 Pallas kernels + L2 JAX BCPNN + AOT.

Never imported at runtime — `make artifacts` runs compile.aot once and
the Rust binary is self-contained afterwards.
"""
